//! Seeded differential fuzz of the column-wise sparse conv path
//! (satellite of the priority-serving PR).
//!
//! For random conv shapes × explicit N:M configs × strip widths × pool
//! sizes {1, 2, 8} × per-layer/per-run thread caps, the full sparse
//! operator (`Conv2dSparseCnhw`: fused im2col/pack + Algorithm-1 SpMM,
//! dispatched on a persistent pool) must agree **bitwise** with a naive
//! dense reference: a scalar GEMM over the unfused `im2col_cnhw` data
//! matrix and the *decompressed* (masked) weights, accumulating each
//! output in ascending reduction order.
//!
//! Why bitwise is the right bar: the sparse kernel accumulates each
//! output column over the retained indices in ascending order, and the
//! reference accumulates over *all* indices in the same order — the
//! skipped terms are exact zeros, and adding `±0.0` to a finite f32
//! accumulator never changes it (under `==`, which treats `-0.0` and
//! `+0.0` as equal). Any deviation — a wrong index, a dropped strip, a
//! racing cap path, a ragged-edge overrun — breaks exact equality and
//! shrinks to a small counterexample.
//!
//! The bitwise properties pin `KernelId::Scalar`: native SIMD backends
//! (AVX2/AVX-512/NEON) reassociate the FMA reduction, so the bitwise
//! bar applies to the scalar oracle only. The kernel axis gets its own
//! differential suite below: every registered-and-available backend
//! vs the scalar oracle under the explicit parity bound
//! (`within_parity_bound`: ≤ `PARITY_ULPS` ULPs, or the
//! magnitude-scaled epsilon arm when cancellation makes ULP distance
//! meaningless).
//!
//! Runs from fixed seeds via `util::prop::check` (with shrinking), so
//! CI is deterministic; `NMPRUNE_PROP_CASES=512` (the scheduled
//! `fuzz-extended` job) scales the same suites up without code changes.

use nmprune::conv::{Conv2dSparseCnhw, ConvShape};
use nmprune::gemm::kernels::{available_ids, within_parity_bound};
use nmprune::gemm::KernelId;
use nmprune::im2col::im2col_cnhw;
use nmprune::tensor::{Dtype, Tensor};
use nmprune::util::{prop, ThreadPool, XorShiftRng};

/// One random fuzz scenario. Data is regenerated from `data_seed`
/// inside the property, so the shrink report stays readable.
#[derive(Debug)]
struct Case {
    shape: ConvShape,
    /// Strip width (VLMAX stand-in).
    v: usize,
    /// Pruning tile height T.
    tile: usize,
    /// Explicit N:M config; `m` always divides `shape.k()`.
    n_keep: usize,
    m: usize,
    pool_size: usize,
    /// Per-layer cap (0 = whole pool) and per-run cap (0 = none),
    /// composed as a min inside the operator.
    layer_cap: usize,
    run_cap: usize,
    data_seed: u64,
}

/// Divisors of `k`, ascending (k is tiny here: ≤ ~200).
fn divisors(k: usize) -> Vec<usize> {
    (1..=k).filter(|d| k % d == 0).collect()
}

fn gen_case(r: &mut XorShiftRng, size: usize) -> Case {
    let kernel = [1usize, 3][r.below(2)];
    let c_in = 1 + r.below(3 + size / 16);
    // Input large enough for the kernel at any stride/pad below.
    let hw = kernel + 1 + r.below(4 + size / 8);
    let c_out = 1 + r.below(8 + size / 8);
    let stride = 1 + r.below(2);
    let pad = r.below(2);
    let batch = 1 + r.below(2);
    let shape = ConvShape::square(batch, c_in, hw, c_out, kernel, stride, pad);
    let k = shape.k();
    // N:M with M drawn from the divisors of K (the pruning contract),
    // N anywhere in 1..=M — covers 1:M, dense N=M, and everything
    // between.
    let divs = divisors(k);
    let m = divs[r.below(divs.len())];
    let n_keep = 1 + r.below(m);
    Case {
        shape,
        v: [4usize, 8, 16, 32][r.below(4)],
        tile: 1 + r.below(8),
        n_keep,
        m,
        pool_size: [1usize, 2, 8][r.below(3)],
        layer_cap: r.below(4),          // 0 = uncapped
        run_cap: [0usize, 1, 2, 9][r.below(4)], // 0 = none; 9 > any pool
        data_seed: r.below(1 << 30) as u64,
    }
}

/// The differential property: sparse path output == naive masked-dense
/// reference, bitwise, for every (pool, cap) composition in the case.
fn sparse_path_matches_naive_dense(c: &Case) -> bool {
    let s = c.shape;
    let mut r = XorShiftRng::new(c.data_seed);
    let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
    let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -0.5, 0.5);
    // Scalar-pinned: the bitwise bar is the scalar oracle's contract;
    // native backends are covered by the parity-bound suite below.
    let op = Conv2dSparseCnhw::new(s, &w, c.v, c.tile, c.n_keep, c.m)
        .with_thread_cap(c.layer_cap)
        .with_kernel(KernelId::Scalar);
    let pool = ThreadPool::shared(c.pool_size);
    let got = op.run_capped(&x, &pool, c.run_cap);
    if got.shape != vec![s.c_out, s.n, s.h_out(), s.w_out()] {
        return false;
    }
    // Naive dense reference: unfused im2col + scalar GEMM over the
    // decompressed masked filter, ascending-k accumulation per output.
    let a = im2col_cnhw(&x, &s);
    let wm = op.weights.decompress();
    let (k, cols) = (s.k(), s.gemm_cols());
    let mut want = vec![0.0f32; s.c_out * cols];
    for o in 0..s.c_out {
        for col in 0..cols {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += wm[o * k + kk] * a[kk * cols + col];
            }
            want[o * cols + col] = acc;
        }
    }
    got.data == want
}

#[test]
fn fuzz_sparse_conv_bitwise_vs_naive_dense() {
    prop::check(
        prop::Config {
            cases: prop::cases_from_env(64),
            seed: 0xF22A,
            max_size: 64,
        },
        gen_case,
        sparse_path_matches_naive_dense,
    );
}

/// Same differential, restricted to serial execution (pool 1, cap 1):
/// separates kernel-correctness failures from scheduling failures when
/// the main property trips.
#[test]
fn fuzz_sparse_conv_serial_bitwise_vs_naive_dense() {
    prop::check(
        prop::Config {
            cases: prop::cases_from_env(64),
            seed: 0xF22B,
            max_size: 48,
        },
        |r, size| {
            let mut c = gen_case(r, size);
            c.pool_size = 1;
            c.layer_cap = 1;
            c.run_cap = 0;
            c
        },
        sparse_path_matches_naive_dense,
    );
}

/// The kernel axis: every registered-and-available native backend runs
/// the same case as the scalar oracle and must agree per element under
/// [`within_parity_bound`] — ≤ `PARITY_ULPS` ULPs, or within the
/// magnitude-scaled epsilon arm when the output is the result of heavy
/// cancellation. The magnitude scale `Σ|wᵢ·xᵢ|` is accumulated in the
/// same naive loop that defines the oracle, so the bound tightens
/// exactly where the reduction is well-conditioned.
fn every_kernel_matches_scalar_oracle(c: &Case) -> bool {
    let s = c.shape;
    let mut r = XorShiftRng::new(c.data_seed);
    let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
    let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -0.5, 0.5);
    let pool = ThreadPool::shared(c.pool_size);
    let oracle_op = Conv2dSparseCnhw::new(s, &w, c.v, c.tile, c.n_keep, c.m)
        .with_thread_cap(c.layer_cap)
        .with_kernel(KernelId::Scalar);
    let oracle = oracle_op.run_capped(&x, &pool, c.run_cap);
    // Per-element |w|·|x| magnitude over the masked weights: the
    // cancellation-aware scale for the epsilon arm of the bound.
    let a = im2col_cnhw(&x, &s);
    let wm = oracle_op.weights.decompress();
    let (k, cols) = (s.k(), s.gemm_cols());
    let mut mag = vec![0.0f32; s.c_out * cols];
    for o in 0..s.c_out {
        for col in 0..cols {
            let mut m = 0.0f32;
            for kk in 0..k {
                m += (wm[o * k + kk] * a[kk * cols + col]).abs();
            }
            mag[o * cols + col] = m;
        }
    }
    for id in available_ids() {
        let op = Conv2dSparseCnhw::new(s, &w, c.v, c.tile, c.n_keep, c.m)
            .with_thread_cap(c.layer_cap)
            .with_kernel(id);
        let got = op.run_capped(&x, &pool, c.run_cap);
        if got.shape != oracle.shape {
            return false;
        }
        for i in 0..got.data.len() {
            if !within_parity_bound(got.data[i], oracle.data[i], mag[i]) {
                return false;
            }
        }
    }
    true
}

#[test]
fn fuzz_every_kernel_backend_vs_scalar_oracle() {
    prop::check(
        prop::Config {
            cases: prop::cases_from_env(48),
            seed: 0xF22C,
            max_size: 48,
        },
        gen_case,
        every_kernel_matches_scalar_oracle,
    );
}

// ----------------------------------------------------------------------
// The dtype axis: the quantized (i8) sparse conv path.
//
// Two contracts, each strictly checkable:
//
// 1. *Accuracy*: the i8 output must sit within a per-element error
//    bound **derived from the actual quantization scales** the op
//    computes — `Σ_retained (½·s_a·|w| + ½·s_w·|a| + ¼·s_w·s_a)`,
//    the triangle-inequality sum of the two half-step rounding errors
//    and their cross term — against an f64 masked-dense reference.
//    The bound is per output element, not a global tolerance, so it
//    tightens automatically on small accumulations.
//
// 2. *Determinism*: integer accumulation is order-independent, so a
//    parallel capped run of ANY available backend must be **bitwise**
//    equal to the serial scalar i8 oracle — a stronger bar than the
//    f32 kernels' ULP parity bound.

/// Contract 1: run the i8 sparse op and check every output element
/// against an f64 masked-dense reference within the derived bound.
/// Factored out of the property so the directed saturation fixtures
/// below reuse it with hand-built extreme tensors.
#[allow(clippy::too_many_arguments)]
fn i8_output_within_derived_bound(
    s: ConvShape,
    x: &Tensor,
    w: &Tensor,
    v: usize,
    tile: usize,
    n_keep: usize,
    m: usize,
    layer_cap: usize,
    pool: &ThreadPool,
    run_cap: usize,
) -> bool {
    let op = Conv2dSparseCnhw::new(s, w, v, tile, n_keep, m)
        .with_thread_cap(layer_cap)
        .with_kernel(KernelId::Scalar)
        .with_dtype(Dtype::I8);
    let got = op.run_capped(x, pool, run_cap);
    if got.shape != vec![s.c_out, s.n, s.h_out(), s.w_out()] {
        return false;
    }
    let a = im2col_cnhw(x, &s);
    let wm = op.weights.decompress();
    let (k, cols) = (s.k(), s.gemm_cols());
    // Recompute the scales exactly as the op does: activations get one
    // panel-wide scale (strip zero-padding never raises the max), each
    // output row its own weight scale over the retained values (the
    // pruned entries of `wm` are exact zeros).
    let sa = a.iter().fold(0.0f32, |mx, x| mx.max(x.abs())) / 127.0;
    for o in 0..s.c_out {
        let sw = wm[o * k..(o + 1) * k]
            .iter()
            .fold(0.0f32, |mx, x| mx.max(x.abs()))
            / 127.0;
        for col in 0..cols {
            let mut want = 0.0f64;
            let mut bound = 0.0f64;
            for kk in 0..k {
                let wv = wm[o * k + kk];
                // Pruned columns are skipped by the compressed kernel
                // and contribute exactly zero — no error term.
                if wv != 0.0 {
                    let av = a[kk * cols + col];
                    want += wv as f64 * av as f64;
                    bound += 0.5 * sa as f64 * wv.abs() as f64
                        + 0.5 * sw as f64 * av.abs() as f64
                        + 0.25 * (sw as f64) * (sa as f64);
                }
            }
            let tol = bound * 1.001 + 1e-5 * want.abs() + 1e-4;
            if (got.data[o * cols + col] as f64 - want).abs() > tol {
                return false;
            }
        }
    }
    true
}

fn quantized_conv_within_derived_bound(c: &Case) -> bool {
    let s = c.shape;
    let mut r = XorShiftRng::new(c.data_seed);
    let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
    let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -0.5, 0.5);
    let pool = ThreadPool::shared(c.pool_size);
    i8_output_within_derived_bound(
        s,
        &x,
        &w,
        c.v,
        c.tile,
        c.n_keep,
        c.m,
        c.layer_cap,
        &pool,
        c.run_cap,
    )
}

#[test]
fn fuzz_quantized_conv_within_derived_bound() {
    prop::check(
        prop::Config {
            cases: prop::cases_from_env(48),
            seed: 0xF22D,
            max_size: 48,
        },
        gen_case,
        quantized_conv_within_derived_bound,
    );
}

/// Contract 2: every available backend, under the case's pool and cap
/// composition, must reproduce the serial scalar i8 output bitwise.
fn every_kernel_i8_bitwise_equals_serial_scalar(c: &Case) -> bool {
    let s = c.shape;
    let mut r = XorShiftRng::new(c.data_seed);
    let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
    let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -0.5, 0.5);
    let serial = ThreadPool::shared(1);
    let oracle = Conv2dSparseCnhw::new(s, &w, c.v, c.tile, c.n_keep, c.m)
        .with_kernel(KernelId::Scalar)
        .with_dtype(Dtype::I8)
        .run_capped(&x, &serial, 1);
    let pool = ThreadPool::shared(c.pool_size);
    for id in available_ids() {
        let op = Conv2dSparseCnhw::new(s, &w, c.v, c.tile, c.n_keep, c.m)
            .with_thread_cap(c.layer_cap)
            .with_kernel(id)
            .with_dtype(Dtype::I8);
        let got = op.run_capped(&x, &pool, c.run_cap);
        if got.shape != oracle.shape || got.data != oracle.data {
            return false;
        }
    }
    true
}

#[test]
fn fuzz_every_kernel_i8_bitwise_vs_serial_scalar() {
    prop::check(
        prop::Config {
            cases: prop::cases_from_env(48),
            seed: 0xF22E,
            max_size: 48,
        },
        gen_case,
        every_kernel_i8_bitwise_equals_serial_scalar,
    );
}

/// Directed i8 corners the generator only hits probabilistically:
/// all-zero activations and all-zero filters (scale-0 arms), extreme
/// magnitudes that push every quantized value to ±127 (saturation),
/// and the degenerate N:M edges — each checked against the derived
/// bound and, where the output is exactly representable, exactly.
#[test]
fn i8_saturation_and_zero_fixtures() {
    let s = ConvShape::square(1, 2, 6, 4, 3, 1, 1);
    let k = s.k();
    let pool = ThreadPool::shared(2);
    let mut r = XorShiftRng::new(0xF22F);

    // All-zero input: the panel scale is 0, every quantized activation
    // is 0, and the output must be exactly zero.
    let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -0.5, 0.5);
    let x0 = Tensor::zeros(&[s.c_in, s.n, s.h_in, s.w_in]);
    let y = Conv2dSparseCnhw::new(s, &w, 8, 4, 2, 3)
        .with_kernel(KernelId::Scalar)
        .with_dtype(Dtype::I8)
        .run_capped(&x0, &pool, 0);
    assert!(y.data.iter().all(|&v| v == 0.0), "zero input must give 0");

    // All-zero filter: every row scale is 0 → exact zeros out.
    let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
    let w0 = Tensor::zeros(&[s.c_out, s.c_in, s.kh, s.kw]);
    let y = Conv2dSparseCnhw::new(s, &w0, 8, 4, 1, k)
        .with_kernel(KernelId::Scalar)
        .with_dtype(Dtype::I8)
        .run_capped(&x, &pool, 0);
    assert!(y.data.iter().all(|&v| v == 0.0), "zero filter must give 0");

    // Saturation: activations at ±1e30 and weights at ±1e3 quantize to
    // exactly ±127 everywhere (the value IS the row max). The derived
    // bound must still hold — scales absorb magnitude symmetrically.
    let xs = Tensor::from_vec(
        &[s.c_in, s.n, s.h_in, s.w_in],
        (0..s.c_in * s.n * s.h_in * s.w_in)
            .map(|i| if i % 2 == 0 { 1.0e30 } else { -1.0e30 })
            .collect(),
    );
    let ws = Tensor::from_vec(
        &[s.c_out, s.c_in, s.kh, s.kw],
        (0..s.c_out * s.c_in * s.kh * s.kw)
            .map(|i| if i % 3 == 0 { -1.0e3 } else { 1.0e3 })
            .collect(),
    );
    assert!(
        i8_output_within_derived_bound(s, &xs, &ws, 8, 4, 2, 3, 0, &pool, 0),
        "saturated extremes must stay within the derived bound"
    );

    // Degenerate N:M edges under i8: 1:K (max sparsity) and K:K
    // (dense-as-sparse), both bound-checked and backend-bitwise.
    for (n_keep, m) in [(1, k), (k, k), (1, 3), (3, 3)] {
        let c = Case {
            shape: s,
            v: 8,
            tile: 4,
            n_keep,
            m,
            pool_size: 2,
            layer_cap: 0,
            run_cap: 0,
            data_seed: 23,
        };
        assert!(
            quantized_conv_within_derived_bound(&c),
            "i8 degenerate N:M bound failed: {c:?}"
        );
        assert!(
            every_kernel_i8_bitwise_equals_serial_scalar(&c),
            "i8 degenerate N:M bitwise failed: {c:?}"
        );
    }
}

/// Directed corners the generator only hits probabilistically: the
/// degenerate N:M configs (1:K max sparsity, K:K dense-as-sparse) on a
/// strided, padded shape across every pool size.
#[test]
fn degenerate_nm_configs_bitwise() {
    let shape = ConvShape::square(2, 3, 7, 5, 3, 2, 1);
    let k = shape.k();
    for (n_keep, m) in [(1, k), (k, k), (1, 3), (3, 3)] {
        for pool_size in [1usize, 2, 8] {
            let c = Case {
                shape,
                v: 8,
                tile: 4,
                n_keep,
                m,
                pool_size,
                layer_cap: 0,
                run_cap: 0,
                data_seed: 7,
            };
            assert!(
                sparse_path_matches_naive_dense(&c),
                "degenerate config failed: {c:?}"
            );
        }
    }
}
