//! Seeded differential fuzz of the column-wise sparse conv path
//! (satellite of the priority-serving PR).
//!
//! For random conv shapes × explicit N:M configs × strip widths × pool
//! sizes {1, 2, 8} × per-layer/per-run thread caps, the full sparse
//! operator (`Conv2dSparseCnhw`: fused im2col/pack + Algorithm-1 SpMM,
//! dispatched on a persistent pool) must agree **bitwise** with a naive
//! dense reference: a scalar GEMM over the unfused `im2col_cnhw` data
//! matrix and the *decompressed* (masked) weights, accumulating each
//! output in ascending reduction order.
//!
//! Why bitwise is the right bar: the sparse kernel accumulates each
//! output column over the retained indices in ascending order, and the
//! reference accumulates over *all* indices in the same order — the
//! skipped terms are exact zeros, and adding `±0.0` to a finite f32
//! accumulator never changes it (under `==`, which treats `-0.0` and
//! `+0.0` as equal). Any deviation — a wrong index, a dropped strip, a
//! racing cap path, a ragged-edge overrun — breaks exact equality and
//! shrinks to a small counterexample.
//!
//! The bitwise properties pin `KernelId::Scalar`: native SIMD backends
//! (AVX2/AVX-512/NEON) reassociate the FMA reduction, so the bitwise
//! bar applies to the scalar oracle only. The kernel axis gets its own
//! differential suite below: every registered-and-available backend
//! vs the scalar oracle under the explicit parity bound
//! (`within_parity_bound`: ≤ `PARITY_ULPS` ULPs, or the
//! magnitude-scaled epsilon arm when cancellation makes ULP distance
//! meaningless).
//!
//! Runs from fixed seeds via `util::prop::check` (with shrinking), so
//! CI is deterministic; `NMPRUNE_PROP_CASES=512` (the scheduled
//! `fuzz-extended` job) scales the same suites up without code changes.

use nmprune::conv::{Conv2dSparseCnhw, ConvShape};
use nmprune::gemm::kernels::{available_ids, within_parity_bound};
use nmprune::gemm::KernelId;
use nmprune::im2col::im2col_cnhw;
use nmprune::tensor::Tensor;
use nmprune::util::{prop, ThreadPool, XorShiftRng};

/// One random fuzz scenario. Data is regenerated from `data_seed`
/// inside the property, so the shrink report stays readable.
#[derive(Debug)]
struct Case {
    shape: ConvShape,
    /// Strip width (VLMAX stand-in).
    v: usize,
    /// Pruning tile height T.
    tile: usize,
    /// Explicit N:M config; `m` always divides `shape.k()`.
    n_keep: usize,
    m: usize,
    pool_size: usize,
    /// Per-layer cap (0 = whole pool) and per-run cap (0 = none),
    /// composed as a min inside the operator.
    layer_cap: usize,
    run_cap: usize,
    data_seed: u64,
}

/// Divisors of `k`, ascending (k is tiny here: ≤ ~200).
fn divisors(k: usize) -> Vec<usize> {
    (1..=k).filter(|d| k % d == 0).collect()
}

fn gen_case(r: &mut XorShiftRng, size: usize) -> Case {
    let kernel = [1usize, 3][r.below(2)];
    let c_in = 1 + r.below(3 + size / 16);
    // Input large enough for the kernel at any stride/pad below.
    let hw = kernel + 1 + r.below(4 + size / 8);
    let c_out = 1 + r.below(8 + size / 8);
    let stride = 1 + r.below(2);
    let pad = r.below(2);
    let batch = 1 + r.below(2);
    let shape = ConvShape::square(batch, c_in, hw, c_out, kernel, stride, pad);
    let k = shape.k();
    // N:M with M drawn from the divisors of K (the pruning contract),
    // N anywhere in 1..=M — covers 1:M, dense N=M, and everything
    // between.
    let divs = divisors(k);
    let m = divs[r.below(divs.len())];
    let n_keep = 1 + r.below(m);
    Case {
        shape,
        v: [4usize, 8, 16, 32][r.below(4)],
        tile: 1 + r.below(8),
        n_keep,
        m,
        pool_size: [1usize, 2, 8][r.below(3)],
        layer_cap: r.below(4),          // 0 = uncapped
        run_cap: [0usize, 1, 2, 9][r.below(4)], // 0 = none; 9 > any pool
        data_seed: r.below(1 << 30) as u64,
    }
}

/// The differential property: sparse path output == naive masked-dense
/// reference, bitwise, for every (pool, cap) composition in the case.
fn sparse_path_matches_naive_dense(c: &Case) -> bool {
    let s = c.shape;
    let mut r = XorShiftRng::new(c.data_seed);
    let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
    let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -0.5, 0.5);
    // Scalar-pinned: the bitwise bar is the scalar oracle's contract;
    // native backends are covered by the parity-bound suite below.
    let op = Conv2dSparseCnhw::new(s, &w, c.v, c.tile, c.n_keep, c.m)
        .with_thread_cap(c.layer_cap)
        .with_kernel(KernelId::Scalar);
    let pool = ThreadPool::shared(c.pool_size);
    let got = op.run_capped(&x, &pool, c.run_cap);
    if got.shape != vec![s.c_out, s.n, s.h_out(), s.w_out()] {
        return false;
    }
    // Naive dense reference: unfused im2col + scalar GEMM over the
    // decompressed masked filter, ascending-k accumulation per output.
    let a = im2col_cnhw(&x, &s);
    let wm = op.weights.decompress();
    let (k, cols) = (s.k(), s.gemm_cols());
    let mut want = vec![0.0f32; s.c_out * cols];
    for o in 0..s.c_out {
        for col in 0..cols {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += wm[o * k + kk] * a[kk * cols + col];
            }
            want[o * cols + col] = acc;
        }
    }
    got.data == want
}

#[test]
fn fuzz_sparse_conv_bitwise_vs_naive_dense() {
    prop::check(
        prop::Config {
            cases: prop::cases_from_env(64),
            seed: 0xF22A,
            max_size: 64,
        },
        gen_case,
        sparse_path_matches_naive_dense,
    );
}

/// Same differential, restricted to serial execution (pool 1, cap 1):
/// separates kernel-correctness failures from scheduling failures when
/// the main property trips.
#[test]
fn fuzz_sparse_conv_serial_bitwise_vs_naive_dense() {
    prop::check(
        prop::Config {
            cases: prop::cases_from_env(64),
            seed: 0xF22B,
            max_size: 48,
        },
        |r, size| {
            let mut c = gen_case(r, size);
            c.pool_size = 1;
            c.layer_cap = 1;
            c.run_cap = 0;
            c
        },
        sparse_path_matches_naive_dense,
    );
}

/// The kernel axis: every registered-and-available native backend runs
/// the same case as the scalar oracle and must agree per element under
/// [`within_parity_bound`] — ≤ `PARITY_ULPS` ULPs, or within the
/// magnitude-scaled epsilon arm when the output is the result of heavy
/// cancellation. The magnitude scale `Σ|wᵢ·xᵢ|` is accumulated in the
/// same naive loop that defines the oracle, so the bound tightens
/// exactly where the reduction is well-conditioned.
fn every_kernel_matches_scalar_oracle(c: &Case) -> bool {
    let s = c.shape;
    let mut r = XorShiftRng::new(c.data_seed);
    let x = Tensor::random(&[s.c_in, s.n, s.h_in, s.w_in], &mut r, -1.0, 1.0);
    let w = Tensor::random(&[s.c_out, s.c_in, s.kh, s.kw], &mut r, -0.5, 0.5);
    let pool = ThreadPool::shared(c.pool_size);
    let oracle_op = Conv2dSparseCnhw::new(s, &w, c.v, c.tile, c.n_keep, c.m)
        .with_thread_cap(c.layer_cap)
        .with_kernel(KernelId::Scalar);
    let oracle = oracle_op.run_capped(&x, &pool, c.run_cap);
    // Per-element |w|·|x| magnitude over the masked weights: the
    // cancellation-aware scale for the epsilon arm of the bound.
    let a = im2col_cnhw(&x, &s);
    let wm = oracle_op.weights.decompress();
    let (k, cols) = (s.k(), s.gemm_cols());
    let mut mag = vec![0.0f32; s.c_out * cols];
    for o in 0..s.c_out {
        for col in 0..cols {
            let mut m = 0.0f32;
            for kk in 0..k {
                m += (wm[o * k + kk] * a[kk * cols + col]).abs();
            }
            mag[o * cols + col] = m;
        }
    }
    for id in available_ids() {
        let op = Conv2dSparseCnhw::new(s, &w, c.v, c.tile, c.n_keep, c.m)
            .with_thread_cap(c.layer_cap)
            .with_kernel(id);
        let got = op.run_capped(&x, &pool, c.run_cap);
        if got.shape != oracle.shape {
            return false;
        }
        for i in 0..got.data.len() {
            if !within_parity_bound(got.data[i], oracle.data[i], mag[i]) {
                return false;
            }
        }
    }
    true
}

#[test]
fn fuzz_every_kernel_backend_vs_scalar_oracle() {
    prop::check(
        prop::Config {
            cases: prop::cases_from_env(48),
            seed: 0xF22C,
            max_size: 48,
        },
        gen_case,
        every_kernel_matches_scalar_oracle,
    );
}

/// Directed corners the generator only hits probabilistically: the
/// degenerate N:M configs (1:K max sparsity, K:K dense-as-sparse) on a
/// strided, padded shape across every pool size.
#[test]
fn degenerate_nm_configs_bitwise() {
    let shape = ConvShape::square(2, 3, 7, 5, 3, 2, 1);
    let k = shape.k();
    for (n_keep, m) in [(1, k), (k, k), (1, 3), (3, 3)] {
        for pool_size in [1usize, 2, 8] {
            let c = Case {
                shape,
                v: 8,
                tile: 4,
                n_keep,
                m,
                pool_size,
                layer_cap: 0,
                run_cap: 0,
                data_seed: 7,
            };
            assert!(
                sparse_path_matches_naive_dense(&c),
                "degenerate config failed: {c:?}"
            );
        }
    }
}
