//! Engine-level integration: executor path agreement on whole graphs,
//! batching-server correctness under load, tuner cache behaviour, and
//! failure injection.

use std::time::Duration;

use nmprune::engine::{ExecConfig, Executor, Server, ServerConfig};
use nmprune::models::{build_model, ModelArch};
use nmprune::tensor::Tensor;
use nmprune::tuner::{cache_key, TuneCache};
use nmprune::util::{allclose, ThreadPool, XorShiftRng};

fn tiny_resnet(batch: usize) -> nmprune::models::Graph {
    build_model(ModelArch::ResNet18, batch, 32)
}

/// The two dense layout paths share deterministic weights (seeded by
/// layer name), so whole-graph outputs must agree.
#[test]
fn dense_nhwc_and_cnhw_executors_agree_end_to_end() {
    let mut rng = XorShiftRng::new(5);
    let x = Tensor::random(&[1, 32, 32, 3], &mut rng, 0.0, 1.0);
    let y_nhwc =
        Executor::new(tiny_resnet(1), ExecConfig::dense_nhwc(ThreadPool::shared(1))).run(&x);
    let y_cnhw =
        Executor::new(tiny_resnet(1), ExecConfig::dense_cnhw(ThreadPool::shared(1))).run(&x);
    assert_eq!(y_nhwc.shape, vec![1, 1000]);
    assert!(
        allclose(&y_nhwc.data, &y_cnhw.data, 1e-3, 1e-4),
        "layout paths diverged"
    );
}

/// Sparse at 0% sparsity must equal the dense CNHW path exactly: the
/// compressed format with every column retained is a dense GEMM.
#[test]
fn sparse_at_zero_sparsity_equals_dense() {
    let mut rng = XorShiftRng::new(6);
    let x = Tensor::random(&[1, 32, 32, 3], &mut rng, 0.0, 1.0);
    let y_dense =
        Executor::new(tiny_resnet(1), ExecConfig::dense_cnhw(ThreadPool::shared(1))).run(&x);
    let y_s0 = Executor::new(
        tiny_resnet(1),
        ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.0),
    )
    .run(&x);
    assert!(allclose(&y_dense.data, &y_s0.data, 1e-4, 1e-5));
}

/// Thread count must not change executor results.
#[test]
fn executor_threading_invariant() {
    let mut rng = XorShiftRng::new(7);
    let x = Tensor::random(&[2, 32, 32, 3], &mut rng, 0.0, 1.0);
    let y1 = Executor::new(
        tiny_resnet(2),
        ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.5),
    )
    .run(&x);
    let y4 = Executor::new(
        tiny_resnet(2),
        ExecConfig::sparse_cnhw(ThreadPool::shared(4), 0.5),
    )
    .run(&x);
    assert_eq!(y1.data, y4.data, "thread count changed results");
}

/// Batch composition must not change per-image results: running images
/// separately equals running them in one batch.
#[test]
fn batch_invariance_of_executor() {
    let mut rng = XorShiftRng::new(8);
    let a = Tensor::random(&[1, 32, 32, 3], &mut rng, 0.0, 1.0);
    let b = Tensor::random(&[1, 32, 32, 3], &mut rng, 0.0, 1.0);
    let exec1 = Executor::new(
        tiny_resnet(1),
        ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.5),
    );
    let ya = exec1.run(&a);
    let yb = exec1.run(&b);
    // Batched input [2, 32, 32, 3].
    let mut xb = Vec::new();
    xb.extend_from_slice(&a.data);
    xb.extend_from_slice(&b.data);
    let exec2 = Executor::new(
        tiny_resnet(2),
        ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.5),
    );
    let y2 = exec2.run(&Tensor::from_vec(&[2, 32, 32, 3], xb));
    assert!(allclose(&y2.data[..1000], &ya.data, 1e-3, 1e-4));
    assert!(allclose(&y2.data[1000..], &yb.data, 1e-3, 1e-4));
}

/// The server's batched replies must equal direct executor runs.
#[test]
fn server_replies_match_direct_execution() {
    let res = 32;
    let server = Server::start(
        tiny_resnet,
        ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.5),
        res,
        ServerConfig {
            batch_sizes: vec![1, 2, 4],
            batch_window: Duration::from_millis(20),
            ..ServerConfig::default()
        },
    );
    let mut rng = XorShiftRng::new(9);
    let images: Vec<Tensor> = (0..6)
        .map(|_| Tensor::random(&[res, res, 3], &mut rng, 0.0, 1.0))
        .collect();
    let handles: Vec<_> = images.iter().map(|im| server.submit(im.clone())).collect();
    let replies: Vec<_> = handles.into_iter().map(|h| h.recv().unwrap()).collect();
    let stats = server.shutdown();
    assert_eq!(stats.served, 6);

    let exec = Executor::new(
        tiny_resnet(1),
        ExecConfig::sparse_cnhw(ThreadPool::shared(1), 0.5),
    );
    for (im, reply) in images.iter().zip(&replies) {
        let mut x = Tensor::from_vec(
            &[1, res, res, 3],
            im.data.clone(),
        );
        x.shape = vec![1, res, res, 3];
        let want = exec.run(&x);
        assert_eq!(reply.logits.len(), 1000);
        assert!(
            allclose(&reply.logits, &want.data, 1e-3, 1e-4),
            "batched reply diverged from direct run"
        );
        assert!(reply.batch >= 1 && reply.batch <= 4);
    }
}

/// Stats must be internally consistent after a burst.
#[test]
fn server_stats_consistency() {
    let res = 32;
    let server = Server::start(
        tiny_resnet,
        ExecConfig::dense_cnhw(ThreadPool::shared(1)),
        res,
        ServerConfig::default(),
    );
    let mut rng = XorShiftRng::new(10);
    let handles: Vec<_> = (0..5)
        .map(|_| server.submit(Tensor::random(&[res, res, 3], &mut rng, 0.0, 1.0)))
        .collect();
    for h in handles {
        h.recv().unwrap();
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 5);
    assert!(stats.throughput_rps > 0.0);
    assert!(stats.mean_batch >= 1.0 && stats.mean_batch <= 4.0);
    assert!(stats.latency.p95 >= stats.latency.median);
}

/// Failure injection: a wrong-shaped image must be rejected at submit.
#[test]
fn server_rejects_bad_image_shape() {
    let server = Server::start(
        tiny_resnet,
        ExecConfig::dense_cnhw(ThreadPool::shared(1)),
        32,
        ServerConfig::default(),
    );
    let bad = Tensor::zeros(&[16, 16, 3]);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        server.submit(bad);
    }));
    assert!(result.is_err(), "mis-shaped submit must panic");
    drop(server.shutdown());
}

/// Failure injection: executor must reject a wrong-shaped input tensor.
#[test]
fn executor_rejects_bad_input() {
    let exec = Executor::new(tiny_resnet(1), ExecConfig::dense_cnhw(ThreadPool::shared(1)));
    let bad = Tensor::zeros(&[1, 16, 16, 3]); // graph built for 32×32
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        exec.run(&bad);
    }));
    assert!(result.is_err(), "mis-shaped input must panic");
}

/// Tuner cache: save → load roundtrip, and memoisation short-circuits
/// the expensive closure.
#[test]
fn tune_cache_roundtrip_and_memoisation() {
    use nmprune::conv::ConvShape;
    let dir = std::env::temp_dir().join("nmprune_tunecache_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cache.tsv");
    let path_s = path.to_str().unwrap();
    let _ = std::fs::remove_file(&path);

    let shape = ConvShape::square(1, 8, 14, 16, 3, 1, 1);
    let key = cache_key(&shape, Some(0.5));
    let mut cache = TuneCache::load(path_s);
    let mut calls = 0;
    let c1 = cache.get_or_tune(key.clone(), || {
        calls += 1;
        nmprune::engine::LayerChoice {
            v: 16,
            tile: 4,
            threads: 2,
            ..Default::default()
        }
    });
    assert_eq!((c1.v, c1.tile, c1.threads), (16, 4, 2));
    let c2 = cache.get_or_tune(key.clone(), || {
        calls += 1;
        nmprune::engine::LayerChoice {
            v: 8,
            tile: 2,
            threads: 1,
            ..Default::default()
        }
    });
    assert_eq!((c2.v, c2.tile), (16, 4), "memoised value must win");
    assert_eq!(calls, 1);
    cache.save(path_s).unwrap();

    let mut reloaded = TuneCache::load(path_s);
    let c3 = reloaded.get_or_tune(key, || panic!("must hit the persisted cache"));
    assert_eq!((c3.v, c3.tile, c3.threads), (16, 4, 2));
}

/// Different sparsity must produce different cache keys.
#[test]
fn tune_cache_keys_distinguish_sparsity() {
    use nmprune::conv::ConvShape;
    let s = ConvShape::square(1, 8, 14, 16, 3, 1, 1);
    assert_ne!(cache_key(&s, Some(0.5)), cache_key(&s, Some(0.75)));
    assert_ne!(cache_key(&s, Some(0.5)), cache_key(&s, None));
}

/// MobileNet (depthwise) and DenseNet (concat) exercise the non-conv
/// ops across both layouts; outputs must agree.
#[test]
fn exotic_archs_agree_across_layouts() {
    for arch in [ModelArch::MobileNetV2, ModelArch::DenseNet121] {
        let mut rng = XorShiftRng::new(12);
        let x = Tensor::random(&[1, 32, 32, 3], &mut rng, 0.0, 1.0);
        let g1 = build_model(arch, 1, 32);
        let g2 = build_model(arch, 1, 32);
        let y_nhwc =
            Executor::new(g1, ExecConfig::dense_nhwc(ThreadPool::shared(1))).run(&x);
        let y_cnhw =
            Executor::new(g2, ExecConfig::dense_cnhw(ThreadPool::shared(1))).run(&x);
        assert!(
            allclose(&y_nhwc.data, &y_cnhw.data, 1e-3, 1e-4),
            "{arch:?} layout paths diverged"
        );
    }
}
