//! Property-based parity for the capped, affinity-aware scheduler
//! (satellite of the pool-scheduling PR): for random GEMM shapes,
//! sparsities, pool sizes {1, 2, 8} and per-call caps 1..=pool+1,
//! capped parallel SpMM / dense GEMM must be bit-for-bit equal to the
//! serial kernels — including caps larger than the pool and strip
//! counts smaller than the cap.
//!
//! Pools come from `ThreadPool::shared`, so the whole property run
//! spawns at most three worker sets no matter how many cases execute.

use nmprune::gemm::threaded::{gemm_dense_parallel_capped, spmm_colwise_parallel_capped};
use nmprune::gemm::{gemm_dense, spmm_colwise};
use nmprune::im2col::pack_data_matrix;
use nmprune::pruning::prune_colwise_adaptive;
use nmprune::util::{prop, ThreadPool};

/// One random scheduling scenario. `Debug` output is the shrink report.
#[derive(Debug)]
struct Case {
    rows: usize,
    k: usize,
    cols: usize,
    v: usize,
    tile: usize,
    sparsity: f64,
    pool_size: usize,
    /// Per-call cap, deliberately allowed to exceed the pool by one.
    cap: usize,
    w: Vec<f32>,
    a: Vec<f32>,
}

fn gen_case(r: &mut nmprune::util::XorShiftRng, size: usize) -> Case {
    let rows = 1 + r.below(8 + size / 4);
    let k = 1 + r.below(8 + size / 2);
    // Columns scale with the size hint; small sizes give strip counts
    // below the cap (and even a single ragged strip).
    let cols = 1 + r.below(4 + 3 * size);
    let v = [4usize, 8, 16, 32][r.below(4)];
    let tile = 1 + r.below(8);
    let sparsity = 0.25 + 0.5 * r.below(3) as f64 / 2.0; // {0.25, 0.5, 0.75}
    let pool_size = [1usize, 2, 8][r.below(3)];
    let cap = 1 + r.below(pool_size + 1); // 1..=pool_size+1
    let w = r.normal_vec(rows * k, 1.0);
    let a = r.normal_vec(k * cols, 1.0);
    Case {
        rows,
        k,
        cols,
        v,
        tile,
        sparsity,
        pool_size,
        cap,
        w,
        a,
    }
}

fn capped_equals_serial(c: &Case) -> bool {
    let p = pack_data_matrix(&c.a, c.k, c.cols, c.v);
    let cp = prune_colwise_adaptive(&c.w, c.rows, c.k, c.tile, c.sparsity);
    let pool = ThreadPool::shared(c.pool_size);
    let serial_sparse = spmm_colwise(&cp, &p);
    let serial_dense = gemm_dense(&c.w, c.rows, &p, c.tile);
    spmm_colwise_parallel_capped(&cp, &p, &pool, Some(c.cap)) == serial_sparse
        && gemm_dense_parallel_capped(&c.w, c.rows, &p, c.tile, &pool, Some(c.cap))
            == serial_dense
}

/// Default-config property run, with the case count overridable via
/// `NMPRUNE_PROP_CASES` (the CI fuzz-extended job runs these suites at
/// 512 cases).
fn check_env<T: std::fmt::Debug>(
    seed: u64,
    gen: impl FnMut(&mut nmprune::util::XorShiftRng, usize) -> T,
    p: impl Fn(&T) -> bool,
) {
    prop::check(
        prop::Config {
            cases: prop::cases_from_env(prop::Config::default().cases),
            seed,
            ..prop::Config::default()
        },
        gen,
        p,
    );
}

#[test]
fn prop_capped_kernels_bitwise_equal_serial() {
    check_env(0x5CED, gen_case, capped_equals_serial);
}

/// The uncapped path (`None`) must agree too — it is the `cap = pool`
/// special case and shares all the chunking arithmetic.
#[test]
fn prop_uncapped_kernels_bitwise_equal_serial() {
    check_env(0x5CEE, gen_case, |c| {
        let p = pack_data_matrix(&c.a, c.k, c.cols, c.v);
        let cp = prune_colwise_adaptive(&c.w, c.rows, c.k, c.tile, c.sparsity);
        let pool = ThreadPool::shared(c.pool_size);
        spmm_colwise_parallel_capped(&cp, &p, &pool, None) == spmm_colwise(&cp, &p)
    });
}

/// Directed corners the generator only hits probabilistically: every
/// (pool, cap) combination from the satellite spec on a strip count
/// smaller than, equal to, and larger than the cap.
#[test]
fn capped_parity_exhaustive_corners() {
    let mut r = nmprune::util::XorShiftRng::new(0xC0DE);
    let (rows, k, v, tile) = (6usize, 12usize, 8usize, 4usize);
    let w = r.normal_vec(rows * k, 1.0);
    for strips in [1usize, 2, 3, 9, 16] {
        let cols = strips * v - v / 2; // ragged final strip
        let a = r.normal_vec(k * cols, 1.0);
        let p = pack_data_matrix(&a, k, cols, v);
        assert_eq!(p.strips, strips);
        let cp = prune_colwise_adaptive(&w, rows, k, tile, 0.5);
        let serial_sparse = spmm_colwise(&cp, &p);
        let serial_dense = gemm_dense(&w, rows, &p, tile);
        for pool_size in [1usize, 2, 8] {
            let pool = ThreadPool::shared(pool_size);
            for cap in 1..=pool_size + 1 {
                assert_eq!(
                    spmm_colwise_parallel_capped(&cp, &p, &pool, Some(cap)),
                    serial_sparse,
                    "sparse strips={strips} pool={pool_size} cap={cap}"
                );
                assert_eq!(
                    gemm_dense_parallel_capped(&w, rows, &p, tile, &pool, Some(cap)),
                    serial_dense,
                    "dense strips={strips} pool={pool_size} cap={cap}"
                );
            }
        }
    }
}
