//! Persistent-pool execution guarantees:
//!
//! * the parallel kernels (`spmm_colwise_parallel`, `gemm_dense_parallel`)
//!   are bit-for-bit equal to the serial kernels across pool sizes
//!   {1, 2, 8}, including strip counts that do not divide evenly among
//!   workers — and across per-call parallelism caps 1..=pool+1;
//! * a long-lived engine runs an entire request stream (100 sequential
//!   inferences) against one `ThreadPool` whose worker set never grows —
//!   the "zero threads spawned per GEMM call" acceptance property.

use std::sync::Arc;

use nmprune::conv::ConvShape;
use nmprune::engine::{ExecConfig, Executor};
use nmprune::gemm::threaded::{
    gemm_dense_parallel, gemm_dense_parallel_capped, spmm_colwise_parallel,
    spmm_colwise_parallel_capped,
};
use nmprune::gemm::{gemm_dense, spmm_colwise};
use nmprune::im2col::pack_data_matrix;
use nmprune::models::{Graph, Op};
use nmprune::pruning::prune_colwise;
use nmprune::tensor::Tensor;
use nmprune::util::{ThreadPool, XorShiftRng};

/// Bit-for-bit parity of parallel vs serial kernels across pool sizes,
/// with strip counts chosen to leave ragged remainders for every worker
/// count tested.
#[test]
fn parallel_kernels_match_serial_bitwise_across_pool_sizes() {
    let mut r = XorShiftRng::new(7);
    for (cols, v) in [
        (205usize, 16usize), // 13 strips: 13 % 2 = 1, 13 % 8 = 5
        (31, 8),             // 4 strips tail-padded: 4 % 8 != 0
        (7, 16),             // single ragged strip
    ] {
        let (rows, k) = (24usize, 36usize);
        let w = r.normal_vec(rows * k, 1.0);
        let a = r.normal_vec(k * cols, 1.0);
        let cp = prune_colwise(&w, rows, k, 8, 2, 4);
        let p = pack_data_matrix(&a, k, cols, v);
        let serial_sparse = spmm_colwise(&cp, &p);
        let serial_dense = gemm_dense(&w, rows, &p, 8);
        for threads in [1usize, 2, 8] {
            let pool = ThreadPool::new(threads);
            assert_eq!(
                spmm_colwise_parallel(&cp, &p, &pool),
                serial_sparse,
                "sparse kernel diverged: cols={cols} v={v} threads={threads}"
            );
            assert_eq!(
                gemm_dense_parallel(&w, rows, &p, 8, &pool),
                serial_dense,
                "dense kernel diverged: cols={cols} v={v} threads={threads}"
            );
        }
    }
}

/// Per-call caps on top of the pool-size sweep: every cap from 1 to one
/// past the pool size must leave the kernels bit-for-bit serial-equal
/// (caps pick *how many* workers participate, never *what* they do).
#[test]
fn capped_dispatch_matches_serial_bitwise_across_pools() {
    let mut r = XorShiftRng::new(8);
    let (rows, k, cols, v) = (24usize, 36usize, 205usize, 16usize);
    let w = r.normal_vec(rows * k, 1.0);
    let a = r.normal_vec(k * cols, 1.0);
    let cp = prune_colwise(&w, rows, k, 8, 2, 4);
    let p = pack_data_matrix(&a, k, cols, v);
    let serial_sparse = spmm_colwise(&cp, &p);
    let serial_dense = gemm_dense(&w, rows, &p, 8);
    for threads in [1usize, 2, 8] {
        let pool = ThreadPool::shared(threads);
        for cap in 1..=threads + 1 {
            assert_eq!(
                spmm_colwise_parallel_capped(&cp, &p, &pool, Some(cap)),
                serial_sparse,
                "sparse pool={threads} cap={cap}"
            );
            assert_eq!(
                gemm_dense_parallel_capped(&w, rows, &p, 8, &pool, Some(cap)),
                serial_dense,
                "dense pool={threads} cap={cap}"
            );
        }
    }
}

/// A small but real conv graph (two convs + GAP + FC) so 100 inferences
/// stay fast in debug builds while still exercising the sparse GEMM and
/// fused-pack hot path on every request.
fn tiny_graph(batch: usize) -> Graph {
    let mut g = Graph::new("tiny", batch);
    let x = g.add("in", Op::Input { c: 3, h: 8, w: 8 }, &[]);
    let c1 = g.add(
        "c1",
        Op::Conv {
            shape: ConvShape::square(batch, 3, 8, 8, 3, 1, 1),
            relu: true,
        },
        &[x],
    );
    let c2 = g.add(
        "c2",
        Op::Conv {
            shape: ConvShape::square(batch, 8, 8, 8, 3, 1, 1),
            relu: true,
        },
        &[c1],
    );
    let gap = g.add("gap", Op::GlobalAvgPool, &[c2]);
    g.add(
        "fc",
        Op::Fc {
            in_features: 8,
            out_features: 10,
        },
        &[gap],
    );
    g
}

/// Acceptance: 100 sequential engine inferences against ONE pool. The
/// pool's worker count is fixed at construction (there is no grow path),
/// so every conv GEMM of every request reuses the same OS threads; the
/// run also checks determinism across the stream.
#[test]
fn hundred_sequential_inferences_reuse_one_pool() {
    let pool = Arc::new(ThreadPool::new(4));
    let exec = Executor::new(tiny_graph(1), ExecConfig::sparse_cnhw(Arc::clone(&pool), 0.5));
    let mut rng = XorShiftRng::new(21);
    let x = Tensor::random(&[1, 8, 8, 3], &mut rng, 0.0, 1.0);
    let first = exec.run(&x);
    assert_eq!(first.shape, vec![1, 10]);
    assert!(first.data.iter().all(|v| v.is_finite()));
    for i in 0..99 {
        let y = exec.run(&x);
        assert_eq!(y.data, first.data, "inference {i} diverged");
    }
    assert_eq!(pool.size(), 4, "worker set must never grow");
    // The config clones share the same pool (one pool per process).
    assert!(Arc::ptr_eq(&pool, &exec.cfg.pool));
}

/// The dense paths run the same stream against the same shared pool.
#[test]
fn dense_paths_share_the_pool_across_requests() {
    let pool = ThreadPool::shared(2);
    for cfg in [
        ExecConfig::dense_cnhw(Arc::clone(&pool)),
        ExecConfig::dense_nhwc(Arc::clone(&pool)),
    ] {
        let exec = Executor::new(tiny_graph(1), cfg);
        let mut rng = XorShiftRng::new(22);
        let x = Tensor::random(&[1, 8, 8, 3], &mut rng, 0.0, 1.0);
        let first = exec.run(&x);
        for _ in 0..20 {
            assert_eq!(exec.run(&x).data, first.data);
        }
    }
    assert_eq!(pool.size(), 2);
}
