//! Serving under load: the acceptance-path integration tests for the
//! load-aware server and the core-pinned pool.
//!
//! - Bursty open-loop traffic (4 bursts × 8 requests, smallest compiled
//!   batch 4, 2-worker pool) answered exactly once in static *and*
//!   adaptive mode with bitwise-identical logits — exercising the
//!   batch-padding path that used to panic and drop requests whenever
//!   fewer requests than the smallest compiled batch were pending.
//! - Shutdown-drain padding: requests stranded below the smallest batch
//!   at shutdown are padded and answered, never dropped.
//! - Pinned-pool parity: OS-level core pinning is placement only —
//!   logits are bitwise identical pinned vs unpinned. On non-Linux
//!   targets pinning is a graceful no-op, so the same test passes
//!   unchanged (nothing to skip, by construction).

use std::sync::Arc;
use std::time::Duration;

use nmprune::engine::{
    ExecConfig, Executor, Priority, QueueDiscipline, Server, ServerConfig, ServerStats,
};
use nmprune::models::{build_model, ModelArch};
use nmprune::tensor::Tensor;
use nmprune::util::{ThreadPool, XorShiftRng};

fn image(res: usize, seed: u64) -> Tensor {
    let mut r = XorShiftRng::new(seed);
    Tensor::random(&[res, res, 3], &mut r, 0.0, 1.0)
}

/// 32 requests in 4 open-loop bursts against a server whose smallest
/// compiled batch is 4, on a 2-worker pool. Returns per-request logits
/// (in submission order) and the final stats.
fn run_bursty(adaptive: bool) -> (Vec<Vec<f32>>, ServerStats) {
    let res = 32;
    let server = Server::start(
        |b| build_model(ModelArch::ResNet18, b, res),
        ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5),
        res,
        ServerConfig {
            batch_sizes: vec![4, 8],
            batch_window: Duration::from_millis(3),
            executors: 2,
            adaptive,
            ..ServerConfig::default()
        },
    );
    let mut handles = Vec::new();
    for burst in 0..4u64 {
        for i in 0..8u64 {
            handles.push(server.submit(image(res, burst * 8 + i)));
        }
        // Open-loop gap: the next burst fires regardless of how far the
        // server got — trailing partial batches exercise zero-padding.
        std::thread::sleep(Duration::from_millis(15));
    }
    let logits: Vec<Vec<f32>> = handles
        .into_iter()
        .map(|rx| {
            let reply = rx.recv().expect("every request must be answered");
            assert_eq!(reply.logits.len(), 1000);
            assert!(reply.logits.iter().all(|v| v.is_finite()));
            assert!(rx.try_recv().is_err(), "exactly one reply per request");
            reply.logits
        })
        .collect();
    let stats = server.shutdown();
    assert_eq!(stats.served, 32, "adaptive={adaptive}");
    assert_eq!(stats.latency.n, 32, "one latency sample per real request");
    (logits, stats)
}

/// Acceptance: bursty load completes in both modes, logits bitwise
/// identical across modes, caps recorded (and within pool bounds) only
/// in adaptive mode.
#[test]
fn bursty_load_static_and_adaptive_agree_bitwise() {
    let (static_logits, static_stats) = run_bursty(false);
    let (adaptive_logits, adaptive_stats) = run_bursty(true);
    assert_eq!(
        static_logits, adaptive_logits,
        "adaptive scheduling changed numerics"
    );
    assert!(static_stats.cap_range.is_none());
    let (lo, hi) = adaptive_stats
        .cap_range
        .expect("adaptive mode must record its chosen caps");
    assert!(lo >= 1 && hi <= 2, "caps {lo}..{hi} outside the 2-worker pool");
}

/// Requests stranded below the smallest compiled batch at shutdown are
/// served via the padded batch, not dropped: the channel closes, the
/// dispatcher's fill loop breaks with 3 pending against a smallest
/// batch of 4, and the drain must still reply to all three.
#[test]
fn shutdown_drain_pads_partial_batches() {
    let res = 32;
    let server = Server::start(
        |b| build_model(ModelArch::ResNet18, b, res),
        ExecConfig::dense_cnhw(ThreadPool::shared(2)),
        res,
        ServerConfig {
            batch_sizes: vec![4],
            batch_window: Duration::from_millis(200),
            executors: 1,
            ..ServerConfig::default()
        },
    );
    let rxs: Vec<_> = (0..3).map(|i| server.submit(image(res, 40 + i))).collect();
    // Shut down while the batcher is still inside its fill window.
    let stats = server.shutdown();
    assert_eq!(stats.served, 3);
    for rx in rxs {
        let reply = rx.try_recv().expect("drained request must have a reply");
        assert_eq!(reply.logits.len(), 1000);
        assert_eq!(reply.batch, 4, "served on the padded smallest executor");
    }
}

/// Core pinning is pure placement: the same model on a pinned and an
/// unpinned pool of equal size produces bitwise-identical logits. Off
/// Linux, `new_pinned` degrades to an unpinned pool, so this test runs
/// (and passes) everywhere without a skip.
#[test]
fn pinned_pool_logits_match_unpinned() {
    let res = 32;
    let mut rng = XorShiftRng::new(77);
    let x = Tensor::random(&[2, res, res, 3], &mut rng, 0.0, 1.0);
    let g = build_model(ModelArch::ResNet18, 2, res);
    let pinned = Arc::new(ThreadPool::new_pinned(3));
    let plain = Arc::new(ThreadPool::new(3));
    let y_pinned =
        Executor::new(g.clone(), ExecConfig::sparse_cnhw(Arc::clone(&pinned), 0.5)).run(&x);
    let y_plain = Executor::new(g, ExecConfig::sparse_cnhw(plain, 0.5)).run(&x);
    assert_eq!(y_pinned.data, y_plain.data, "pinning changed numerics");
    assert!(
        pinned.pinned_workers() <= 3,
        "at most one successful pin per worker"
    );
    if !cfg!(target_os = "linux") {
        assert_eq!(pinned.pinned_workers(), 0, "pinning must no-op off Linux");
    }
}

/// Acceptance (tentpole): mixed-priority open-loop traffic — bursts of
/// interleaved interactive-with-deadline and background requests —
/// served under the Priority discipline answers every request exactly
/// once, drains the background class fully, attributes stats per class,
/// and produces logits **bitwise identical** to the FIFO discipline:
/// priorities and deadlines are scheduling, never numerics.
#[test]
fn mixed_priority_load_matches_fifo_bitwise_and_drains_background() {
    let res = 32;
    let run = |discipline: QueueDiscipline| -> (Vec<Vec<f32>>, ServerStats) {
        let server = Server::start(
            |b| build_model(ModelArch::ResNet18, b, res),
            ExecConfig::sparse_cnhw(ThreadPool::shared(2), 0.5),
            res,
            ServerConfig {
                batch_sizes: vec![2, 4],
                batch_window: Duration::from_millis(3),
                executors: 2,
                adaptive: true,
                discipline,
                ..ServerConfig::default()
            },
        );
        let mut handles = Vec::new();
        for burst in 0..3u64 {
            for i in 0..8u64 {
                let seed = burst * 8 + i;
                // Interleave classes: evens interactive with a generous
                // deadline (tracked, not expected to miss), odds
                // background without one.
                let (prio, ddl) = if i % 2 == 0 {
                    (Priority::Interactive, Some(Duration::from_secs(30)))
                } else {
                    (Priority::Batch, None)
                };
                handles.push(server.submit_with(image(res, seed), prio, ddl));
            }
            // Open-loop gap: the next burst fires regardless of how far
            // the server got.
            std::thread::sleep(Duration::from_millis(10));
        }
        let logits: Vec<Vec<f32>> = handles
            .into_iter()
            .map(|rx| {
                let reply = rx.recv().expect("every request must be answered");
                assert_eq!(reply.logits.len(), 1000);
                assert!(rx.try_recv().is_err(), "exactly one reply per request");
                reply.logits
            })
            .collect();
        (logits, server.shutdown())
    };
    let (fifo_logits, fifo_stats) = run(QueueDiscipline::Fifo);
    let (prio_logits, prio_stats) = run(QueueDiscipline::Priority);
    assert_eq!(
        fifo_logits, prio_logits,
        "priority/deadline scheduling changed numerics"
    );
    for (label, stats) in [("fifo", &fifo_stats), ("priority", &prio_stats)] {
        assert_eq!(stats.served, 24, "{label}");
        assert_eq!(
            stats.class(Priority::Interactive).served,
            12,
            "{label}: interactive class fully served"
        );
        assert_eq!(
            stats.class(Priority::Batch).served,
            12,
            "{label}: background class fully drained, not starved"
        );
        assert_eq!(stats.class(Priority::Interactive).deadline_total, 12, "{label}");
        assert_eq!(stats.class(Priority::Batch).deadline_total, 0, "{label}");
        // Per-class samples partition the overall latency samples, and
        // the batch histogram accounts for every batch executed.
        assert_eq!(
            stats.class(Priority::Interactive).latency.n
                + stats.class(Priority::Batch).latency.n,
            stats.latency.n,
            "{label}"
        );
        let hist_batches: usize = stats.batch_hist.iter().map(|&(_, n)| n).sum();
        assert!(hist_batches > 0, "{label}: batch histogram populated");
        assert!(
            stats.batch_hist.iter().all(|&(b, _)| b == 2 || b == 4),
            "{label}: only compiled sizes appear: {:?}",
            stats.batch_hist
        );
    }
}

/// An adaptive server running on an explicitly pinned pool (the
/// NMPRUNE_PIN=1 deployment shape, which CI also exercises through the
/// env var on shared pools) serves a mixed trickle + burst load
/// exactly once.
#[test]
fn adaptive_server_on_pinned_pool_serves_all() {
    let res = 32;
    let pool = Arc::new(ThreadPool::new_pinned(2));
    let server = Server::start(
        |b| build_model(ModelArch::ResNet18, b, res),
        ExecConfig::dense_cnhw(pool),
        res,
        ServerConfig {
            batch_sizes: vec![2, 4],
            batch_window: Duration::from_millis(2),
            executors: 2,
            adaptive: true,
            ..ServerConfig::default()
        },
    );
    // Trickle…
    for i in 0..2 {
        let rx = server.submit(image(res, 60 + i));
        assert_eq!(rx.recv().expect("trickle reply").logits.len(), 1000);
    }
    // …then a burst.
    let rxs: Vec<_> = (0..8).map(|i| server.submit(image(res, 70 + i))).collect();
    for rx in rxs {
        let reply = rx.recv().expect("burst reply");
        assert_eq!(reply.logits.len(), 1000);
        assert!(rx.try_recv().is_err(), "exactly once");
    }
    let stats = server.shutdown();
    assert_eq!(stats.served, 10);
    assert!(stats.cap_range.is_some(), "adaptive caps observable");
}
