//! Integration coverage for the bench-trajectory layer: `Report` JSON
//! round-trips through the public API (save → load), `diff_reports`
//! regression semantics on synthetic data, and the
//! `nmprune bench-diff` CLI exit-code contract — 0 clean, 1 gated
//! regression beyond threshold, 2 usage or unreadable input.

use std::path::PathBuf;
use std::process::{Command, Output};

use nmprune::benchlib::report::DiffStatus;
use nmprune::benchlib::{diff_reports, BenchRecord, RecordConfig, Report};
use nmprune::util::Summary;

fn record(case: &str, config: RecordConfig, median: f64, pct: Option<f64>) -> BenchRecord {
    BenchRecord {
        bench: "perf_hotpath".into(),
        case: case.into(),
        config,
        unit: "ns".into(),
        summary: Summary::of(&[median]),
        gflops: pct.map(|p| p / 10.0),
        pct_of_peak: pct,
        gate: true,
    }
}

fn report_with(records: Vec<BenchRecord>) -> Report {
    let mut r = Report::new("perf_hotpath");
    r.records = records;
    r
}

fn tmp_path(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("nmprune_bench_{}_{name}", std::process::id()));
    p
}

#[test]
fn save_load_roundtrip_via_public_api() {
    let mut r = report_with(vec![
        record("gemm", RecordConfig::new(2, 8, 1), 1.0e6, Some(40.0)),
        record("fused pack", RecordConfig::NONE, 250.0, None),
    ]);
    r.records[1].unit = "cycles".into();
    // An empty summary (n = 0) and an ungated record must survive too.
    r.records.push(BenchRecord {
        bench: "perf_hotpath".into(),
        case: "empty".into(),
        config: RecordConfig::NONE,
        unit: "ns".into(),
        summary: Summary::empty(),
        gflops: None,
        pct_of_peak: None,
        gate: false,
    });

    let path = tmp_path("roundtrip.json");
    r.save(&path).unwrap();
    let back = Report::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(back.suite, "perf_hotpath");
    assert_eq!(back.records.len(), r.records.len());
    for (a, b) in back.records.iter().zip(&r.records) {
        assert_eq!(a.key(), b.key());
        assert_eq!(a.unit, b.unit);
        assert_eq!(a.gate, b.gate);
        assert_eq!(a.summary, b.summary);
        assert_eq!(a.pct_of_peak, b.pct_of_peak);
    }
    // A round-tripped report self-diffs clean even at a tiny threshold.
    assert!(!diff_reports(&r, &back, 0.001).has_regressions());
}

#[test]
fn injected_regression_fails_and_config_change_does_not() {
    let old = report_with(vec![
        record("kernel", RecordConfig::new(2, 8, 1), 1000.0, Some(50.0)),
        record("moved", RecordConfig::new(2, 8, 1), 500.0, None),
    ]);
    let new = report_with(vec![
        // %-of-peak fell 50 → 30: a 40% drop, far past a 10% threshold.
        record("kernel", RecordConfig::new(2, 8, 1), 1500.0, Some(30.0)),
        // Same case re-measured at a different config: identity changes,
        // so this is removed + added, never a false regression.
        record("moved", RecordConfig::new(4, 8, 1), 5000.0, None),
    ]);

    let d = diff_reports(&old, &new, 10.0);
    assert!(d.has_regressions());
    assert_eq!(d.regressions(), 1);
    let reg = d
        .entries
        .iter()
        .find(|e| e.status == DiffStatus::Regression)
        .unwrap();
    assert!(reg.key.contains("kernel"));
    assert_eq!(reg.metric, "%peak");
    let only_old = d.entries.iter().filter(|e| e.status == DiffStatus::OnlyOld);
    let only_new = d.entries.iter().filter(|e| e.status == DiffStatus::OnlyNew);
    assert_eq!(only_old.count(), 1);
    assert_eq!(only_new.count(), 1);

    // A threshold past the injected delta tolerates it.
    assert!(!diff_reports(&old, &new, 60.0).has_regressions());
}

fn run_diff(args: &[&str]) -> Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_nmprune"));
    cmd.arg("bench-diff").args(args);
    cmd.output().expect("spawn nmprune bench-diff")
}

#[test]
fn bench_diff_cli_exit_codes() {
    let rec = record("k", RecordConfig::new(2, 8, 1), 1000.0, Some(50.0));
    let base = report_with(vec![rec]);
    let mut slow = base.clone();
    slow.records[0].summary = Summary::of(&[1500.0]);
    slow.records[0].pct_of_peak = Some(30.0);

    let old_p = tmp_path("cli_old.json");
    let new_p = tmp_path("cli_new.json");
    base.save(&old_p).unwrap();
    slow.save(&new_p).unwrap();
    let old = old_p.to_str().unwrap();
    let new = new_p.to_str().unwrap();

    // Self-diff is clean: exit 0.
    let out = run_diff(&[old, old]);
    let err = String::from_utf8_lossy(&out.stderr).into_owned();
    assert!(out.status.success(), "self-diff failed: {err}");

    // Injected >10% regression: exit 1, row flagged in the table.
    let out = run_diff(&[old, new, "--threshold-pct", "10"]);
    assert_eq!(out.status.code(), Some(1));
    let text = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(text.contains("REGRESSION"), "{text}");

    // The same delta under a generous threshold passes.
    let out = run_diff(&[old, new, "--threshold-pct", "60"]);
    assert!(out.status.success());

    // Missing operands: usage error, exit 2.
    let out = run_diff(&[old]);
    assert_eq!(out.status.code(), Some(2));

    // Unreadable input: exit 2.
    let out = run_diff(&["/nonexistent/bench_old.json", new]);
    assert_eq!(out.status.code(), Some(2));

    // Wrong schema version: load error, exit 2.
    let bad_p = tmp_path("cli_bad.json");
    let doc = r#"{"schema_version": 99, "suite": "s", "records": []}"#;
    std::fs::write(&bad_p, doc).unwrap();
    let out = run_diff(&[bad_p.to_str().unwrap(), new]);
    assert_eq!(out.status.code(), Some(2));

    std::fs::remove_file(&old_p).ok();
    std::fs::remove_file(&new_p).ok();
    std::fs::remove_file(&bad_p).ok();
}
