//! Integration test: AOT numerics parity across the language boundary.
//!
//! For every artifact in `artifacts/manifest.tsv`, load the HLO text,
//! compile on the PJRT CPU client, execute with the sample input
//! `aot.py` saved, and compare against the Python-side expected output.
//! This is the proof that the three layers compose: Pallas kernels (L1)
//! inside the jax model (L2) produce the same numbers when run from the
//! Rust request path (L3).
//!
//! Skips silently (with a note) when `make artifacts` has not run.

use std::path::{Path, PathBuf};

use nmprune::runtime::{read_manifest, PjrtRuntime};
use nmprune::util::allclose;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Parse the flat-f32 text format written by aot.py: dims line, then
/// one value per line.
fn load_flat(path: &Path) -> (Vec<usize>, Vec<f32>) {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| panic!("{path:?}: {e}"));
    let mut lines = text.lines();
    let dims: Vec<usize> = lines
        .next()
        .expect("dims line")
        .split_whitespace()
        .map(|t| t.parse().expect("dim"))
        .collect();
    let data: Vec<f32> = lines
        .filter(|l| !l.trim().is_empty())
        .map(|l| l.trim().parse().expect("f32"))
        .collect();
    assert_eq!(dims.iter().product::<usize>(), data.len(), "{path:?}");
    (dims, data)
}

#[test]
fn every_artifact_matches_python_expected_output() {
    let dir = artifacts_dir();
    let manifest = dir.join("manifest.tsv");
    if !manifest.exists() {
        eprintln!("skipping AOT parity test: run `make artifacts` first");
        return;
    }
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let entries = read_manifest(&manifest).expect("manifest");
    assert!(!entries.is_empty());
    for e in &entries {
        rt.load_hlo_text(&e.name, &e.file, e.input_arity)
            .unwrap_or_else(|err| panic!("{}: {err}", e.name));
        // Gather inputs.
        let inputs: Vec<(Vec<usize>, Vec<f32>)> = (0..e.input_arity)
            .map(|i| load_flat(&dir.join(format!("{}.input{i}.txt", e.name))))
            .collect();
        let input_refs: Vec<(&[f32], &[usize])> = inputs
            .iter()
            .map(|(dims, data)| (data.as_slice(), dims.as_slice()))
            .collect();
        let outputs = rt
            .execute_f32(&e.name, &input_refs)
            .unwrap_or_else(|err| panic!("{}: {err}", e.name));
        // Compare each output against the Python-side expectation.
        for (i, got) in outputs.iter().enumerate() {
            let (_, want) = load_flat(&dir.join(format!("{}.expected{i}.txt", e.name)));
            assert!(
                allclose(got, &want, 1e-4, 1e-5),
                "{} output {i}: max diff {}",
                e.name,
                nmprune::util::max_abs_diff(got, &want)
            );
        }
        println!("{}: OK ({} outputs)", e.name, outputs.len());
    }
}

#[test]
fn artifact_reexecution_is_deterministic() {
    let dir = artifacts_dir();
    let manifest = dir.join("manifest.tsv");
    if !manifest.exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = PjrtRuntime::cpu().expect("pjrt cpu client");
    let entries = read_manifest(&manifest).expect("manifest");
    let e = &entries[0];
    rt.load_hlo_text(&e.name, &e.file, e.input_arity).unwrap();
    let inputs: Vec<(Vec<usize>, Vec<f32>)> = (0..e.input_arity)
        .map(|i| load_flat(&dir.join(format!("{}.input{i}.txt", e.name))))
        .collect();
    let input_refs: Vec<(&[f32], &[usize])> = inputs
        .iter()
        .map(|(dims, data)| (data.as_slice(), dims.as_slice()))
        .collect();
    let run = || rt.execute_f32(&e.name, &input_refs).unwrap();
    assert_eq!(run(), run(), "same input must give identical output");
}
