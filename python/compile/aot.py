"""AOT lowering: jax/Pallas → HLO **text** artifacts for the Rust runtime.

HLO text, NOT ``lowered.compile()``/``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to --out-dir, default ../artifacts):
  conv_dense       one conv layer, dense GEMM kernel path
  conv_sparse50    same layer, column-wise N:M at 50% sparsity
  smallcnn_b{1,2,4} full smallcnn forward per batch size

For each artifact a sample input (``.input.txt``) and expected output
(``.expected.txt``) are saved as flat f32 text for the Rust-side
numerics parity test, plus a ``manifest.tsv`` the runtime loads.

Usage: python -m compile.aot [--out-dir DIR]
"""

from __future__ import annotations

import argparse
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def save_flat(path: str, arr: np.ndarray) -> None:
    """Dims on line 1 (space-separated), flat f32 values one per line."""
    arr = np.asarray(arr, np.float32)
    with open(path, "w") as f:
        f.write(" ".join(str(d) for d in arr.shape) + "\n")
        for v in arr.reshape(-1):
            f.write(f"{v:.9g}\n")


def lower_artifact(fn, example_inputs, name: str, out_dir: str,
                   manifest: list[str], description: str) -> None:
    """Lower fn(*inputs) to HLO text + save sample input/output pairs."""
    specs = [jax.ShapeDtypeStruct(np.asarray(x).shape, jnp.float32)
             for x in example_inputs]
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    # Sample I/O for the Rust parity test.
    outputs = fn(*[jnp.asarray(x) for x in example_inputs])
    if not isinstance(outputs, tuple):
        outputs = (outputs,)
    for i, x in enumerate(example_inputs):
        save_flat(os.path.join(out_dir, f"{name}.input{i}.txt"), np.asarray(x))
    for i, y in enumerate(outputs):
        save_flat(os.path.join(out_dir, f"{name}.expected{i}.txt"), np.asarray(y))
    manifest.append(f"{name}\t{name}.hlo.txt\t{len(example_inputs)}\t{description}")
    print(f"  {name}: {len(text)} chars, {len(example_inputs)} input(s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--res", type=int, default=16, help="smallcnn input resolution")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    print(f"writing artifacts to {out_dir}")

    manifest: list[str] = []
    rng = np.random.default_rng(7)
    params = model.init_params(seed=0)

    # Weights are runtime *parameters*, never baked constants: the HLO
    # text printer elides large literals (`constant({...})`) and the
    # xla_extension-0.5.1 parser zero-fills them. Caught by the Rust
    # aot_parity test; recorded in EXPERIMENTS.md §Gotchas.

    # --- single conv layer artifacts (conv2 geometry of smallcnn) ----
    w2f = model.filter_matrix(params["conv2"])  # [32, 144]
    x_conv = rng.normal(0, 1, (16, 1, args.res, args.res)).astype(np.float32)

    def conv_dense(x, f):
        return (model.conv2d_kernels_dense(x, f, kh=3, kw=3, stride=2,
                                           pad=1, v=32, tile=8),)

    nret = model.ref.retained_for_sparsity(w2f.shape[1], 0.5)
    w_vals, idx, _ = model.pack_colwise_weights(w2f, 8, nret, w2f.shape[1])
    idx_f = idx.astype(np.float32)

    def conv_sparse(x, vals, ix):
        return (model.conv2d_kernels_sparse(x, vals, ix, c_out=32, kh=3,
                                            kw=3, stride=2, pad=1, v=32),)

    lower_artifact(conv_dense, [x_conv, w2f], "conv_dense", out_dir, manifest,
                   "conv2 16->32 3x3 s2, dense GEMM kernel")
    lower_artifact(conv_sparse, [x_conv, w_vals, idx_f], "conv_sparse50",
                   out_dir, manifest, "conv2 16->32 3x3 s2, column-wise N:M 50%")

    # --- full smallcnn per batch size (the PJRT serving artifacts) ----
    operands = model.small_cnn_operands(params, tile=8, sparsity=0.5)
    for batch in (1, 2, 4):
        x = rng.normal(0, 1, (batch, args.res, args.res, 3)).astype(np.float32)

        def fwd(xb, *ops):
            return (model.small_cnn_fwd_operands(xb, *ops, v=32, tile=8),)

        lower_artifact(fwd, [x] + operands, f"smallcnn_b{batch}", out_dir,
                       manifest,
                       f"smallcnn fwd batch={batch}, sparse 50% kernel path")

    # --- residual block (skip-connection composition through the
    #     Pallas kernels; served standalone by the runtime) ------------
    rb_c = 16
    rb_params = model.init_resblock_params(rb_c, seed=3)
    rb_ops = model.resblock_operands(rb_params, tile=8, sparsity=0.5)
    x_rb = rng.normal(0, 1, (rb_c, 1, args.res, args.res)).astype(np.float32)

    def rb_fwd(x, c1v, c1i, c2v, c2i):
        return (model.resblock_fwd_operands(x, c1v, c1i, c2v, c2i,
                                            c=rb_c, v=32),)

    lower_artifact(rb_fwd, [x_rb] + rb_ops, "resblock", out_dir, manifest,
                   f"BasicBlock c={rb_c} 3x3/3x3 identity skip, sparse 50%")

    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("# name\tfile\tinput_arity\tdescription\n")
        f.write("\n".join(manifest) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
