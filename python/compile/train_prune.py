"""Accuracy experiments (Table 1 / Table 2 accuracy columns).

Substitution (DESIGN.md §2): the paper one-shot-prunes torchvision
checkpoints and retrains 90 epochs on ImageNet; we train the smallcnn on
the deterministic synthetic task, one-shot prune with each variant, and
fine-tune with mask projection. The paper's accuracy *claim* is ordinal
— row-wise N:M ≥ column-wise adaptive-M ≫ column-wise fixed-M at equal
sparsity, degradation grows with sparsity — which is a property of the
mask constraint sets, not of ImageNet.

Variants (paper §4.5):
  1. row N:M, M=4          (= column-wise with tile 1)
  2. column-wise N:M, M=4, tile 8   (the constrained case)
  3. column-wise adaptive M = K, tile 8  (the paper's full method)

Usage: python -m compile.train_prune [--steps 600] [--finetune 300]
                                     [--out artifacts/accuracy_table.md]
"""

from __future__ import annotations

import argparse
import os

import numpy as np
import jax
import jax.numpy as jnp

from . import model
from .kernels import ref


# ---------------------------------------------------------------------
# Hand-rolled Adam (no optax offline)

def adam_init(params):
    return {
        k: {"m": jnp.zeros_like(jnp.asarray(v)), "v": jnp.zeros_like(jnp.asarray(v))}
        for k, v in params.items()
    }


def adam_update(params, grads, state, step, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    new_params, new_state = {}, {}
    t = step + 1
    for k in params:
        g = grads[k]
        m = b1 * state[k]["m"] + (1 - b1) * g
        v = b2 * state[k]["v"] + (1 - b2) * g * g
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        new_params[k] = jnp.asarray(params[k]) - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_state[k] = {"m": m, "v": v}
    return new_params, new_state


# ---------------------------------------------------------------------
# Training loops

def make_step(masks):
    """Jitted Adam step with optional mask projection."""

    def loss_fn(params, x, y):
        logits = model.small_cnn_fwd_jnp(params, x, masks)
        return model.cross_entropy(logits, y)

    @jax.jit
    def step(params, state, t, x, y):
        loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
        params, state = adam_update(params, grads, state, t)
        return params, state, loss

    return step


def train(params, steps: int, masks=None, seed: int = 0, batch: int = 64,
          lr_note: str = ""):
    rng = np.random.default_rng(seed)
    step_fn = make_step(masks)
    state = adam_init(params)
    params = {k: jnp.asarray(v) for k, v in params.items()}
    for t in range(steps):
        x, y = model.synth_batch(rng, batch)
        params, state, loss = step_fn(params, state, t, x, y)
        if t % 100 == 0 or t == steps - 1:
            print(f"  step {t:4d} loss {float(loss):.4f} {lr_note}")
    return {k: np.asarray(v) for k, v in params.items()}


def evaluate(params, masks=None, n: int = 2000, seed: int = 99) -> float:
    rng = np.random.default_rng(seed)
    x, y = model.synth_batch(rng, n)
    logits = model.small_cnn_fwd_jnp(params, x, masks)
    return model.accuracy(logits, jnp.asarray(y))


# ---------------------------------------------------------------------
# Pruning variants on the prunable layers (never the first conv, §4.1.2)

PRUNABLE = ("conv2", "conv3")


def masks_for_variant(params, variant: str, sparsity: float) -> dict:
    """Build filter-matrix masks [C_out, K] per prunable layer."""
    masks = {}
    for name in PRUNABLE:
        f = model.filter_matrix(params[name])
        n4 = max(ref.retained_for_sparsity(4, sparsity), 1)
        if variant == "row":
            # row-based N:M with M=4 (tile 1).
            mask = ref.prune_rownm(f, n4, 4)
        elif variant == "colwise_m4":
            mask, _ = ref.prune_colwise(f, 8, n4, 4)
        elif variant == "colwise_adaptive":
            mask, _ = ref.prune_colwise_adaptive(f, 8, sparsity)
        else:
            raise ValueError(variant)
        masks[name] = mask
    return masks


def mask_sparsity(masks: dict) -> float:
    total = sum(m.size for m in masks.values())
    kept = sum(int(m.sum()) for m in masks.values())
    return 1.0 - kept / total


VARIANT_LABELS = {
    "row": "row N:M (M=4, T=1)",
    "colwise_m4": "column-wise N:M (M=4, T=8)",
    "colwise_adaptive": "column-wise adaptive M (T=8)",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--finetune", type=int, default=300)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts", "accuracy_table.md"))
    args = ap.parse_args()

    print("=== training dense baseline ===")
    params = train(model.init_params(seed=0), args.steps, seed=1,
                   lr_note="(dense)")
    dense_acc = evaluate(params)
    print(f"dense accuracy: {dense_acc:.3f}")

    rows = [("Dense", "-", f"{dense_acc * 100:.1f}%", "-")]
    for sparsity in (0.25, 0.50, 0.75):
        for variant in ("row", "colwise_m4", "colwise_adaptive"):
            label = VARIANT_LABELS[variant]
            masks = masks_for_variant(params, variant, sparsity)
            pre = evaluate(params, masks)
            print(f"=== {label} @ {sparsity:.0%}: one-shot acc {pre:.3f}, "
                  f"mask sparsity {mask_sparsity(masks):.2f} ===")
            tuned = train(dict(params), args.finetune, masks=masks,
                          seed=2, lr_note=f"({variant}@{sparsity})")
            acc = evaluate(tuned, masks)
            print(f"  fine-tuned accuracy: {acc:.3f}")
            rows.append((f"{sparsity:.0%}", label, f"{acc * 100:.1f}%",
                         f"{pre * 100:.1f}%"))

    # Render the Table-1 analogue.
    lines = [
        "# Accuracy vs pruning variant (Table 1 analogue, synthnet/smallcnn)",
        "",
        "| Sparsity | Variant | Top-1 (fine-tuned) | Top-1 (one-shot) |",
        "|---|---|---|---|",
    ]
    for r in rows:
        lines.append("| " + " | ".join(r) + " |")
    table = "\n".join(lines) + "\n"
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(table)
    print("\n" + table)
    print(f"written to {args.out}")


if __name__ == "__main__":
    main()
