"""Layer-1 Pallas kernel: dense tiled GEMM over packed strips — the
dense baseline the sparse kernels are compared against.

Grid: (strips, row_tiles); per step one ``(T, K)·(K, V)`` MXU matmul.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def dense_gemm(a_packed, w, tile: int, *, interpret: bool = True):
    """``C = W · A`` with A packed.

    a_packed: [strips, K, V]
    w:        [rows, K] (rows padded to a multiple of `tile` internally)
    returns:  [rows, strips*V] (caller crops cols)
    """
    strips, k, v = a_packed.shape
    rows = w.shape[0]
    rows_pad = -(-rows // tile) * tile
    if rows_pad != rows:
        w = jnp.concatenate(
            [jnp.asarray(w), jnp.zeros((rows_pad - rows, k), jnp.float32)]
        )
    row_tiles = rows_pad // tile

    def kernel(a_ref, w_ref, o_ref):
        o_ref[:, 0, :] = w_ref[...] @ a_ref[0]

    out = pl.pallas_call(
        kernel,
        grid=(strips, row_tiles),
        in_specs=[
            pl.BlockSpec((1, k, v), lambda s, rt: (s, 0, 0)),
            pl.BlockSpec((tile, k), lambda s, rt: (rt, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1, v), lambda s, rt: (rt, s, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, strips, v), jnp.float32),
        interpret=interpret,
    )(a_packed, jnp.asarray(w, jnp.float32))
    return out.reshape(rows_pad, strips * v)[:rows]


def dense_gemm_result(w: np.ndarray, a: np.ndarray, tile: int, v: int):
    """prune-free helper: pack + kernel, cropped to [rows, cols]."""
    from . import ref

    cols = a.shape[1]
    packed = jnp.asarray(ref.pack_data_matrix(a, v))
    return dense_gemm(packed, w, tile)[:, :cols]
