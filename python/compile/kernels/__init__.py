"""Layer-1 Pallas kernels (build-time only; interpret=True on CPU).

* ``colwise_spmm`` — Algorithm 1, compressed-operand MXU formulation.
* ``im2col_pack`` — Algorithm 2, fused im2col + strip packing.
* ``dense_gemm`` — dense tiled baseline.
* ``nm_row_spmm`` — conventional row-based N:M baseline.
* ``ref`` — pure jnp/numpy oracles + pruning helpers.
"""

from . import ref  # noqa: F401
from .colwise_spmm import colwise_spmm, colwise_spmm_dense_result, pack_colwise_weights  # noqa: F401
from .dense_gemm import dense_gemm, dense_gemm_result  # noqa: F401
from .im2col_pack import fused_im2col_pack  # noqa: F401
from .nm_row_spmm import rownm_spmm, rownm_spmm_result  # noqa: F401
