"""Layer-1 Pallas kernel: fused im2col + data packing (Algorithm 2).

One grid step materialises one packed strip ``[K, V]`` straight from the
CNHW feature map — the intermediate ``A`` matrix never exists. Source
coordinates are computed in-kernel from the strip's program id with
vectorised index arithmetic; padding taps resolve to 0 via a mask
(`jnp.where`), the counterpart of the paper's dynamic-VL boundary
handling: out-of-range lanes are never *read*, matching §3.2's
"avoids copying zero-padding regions".

The BlockSpec is the HBM↔VMEM schedule: the feature map stays resident,
each step streams out one strip — what the paper expresses with vector
stores into the strip buffer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def fused_im2col_pack(x, kh: int, kw: int, stride: int, pad: int, v: int,
                      *, interpret: bool = True):
    """x: [C, N, H, W] (CNHW) → packed [strips, K, V] with K = kh·kw·C.

    Matches ``ref.fused_im2col_pack_ref`` bit-for-bit.
    """
    c_in, n, h, w = x.shape
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    cols = n * ho * wo
    k = kh * kw * c_in
    strips = max(-(-cols // v), 1)

    def kernel(x_ref, o_ref):
        s = pl.program_id(0)
        xf = x_ref[...].reshape(-1)
        # Static per-row tap coordinates (row = (ky*kw + kx)*C + c),
        # computed in-kernel (captured constants are rejected by pallas).
        row_ids = jnp.arange(k, dtype=jnp.int32)
        row_c = row_ids % c_in
        row_kx = (row_ids // c_in) % kw
        row_ky = row_ids // (c_in * kw)
        # Columns covered by this strip.
        col = s * v + jnp.arange(v, dtype=jnp.int32)        # [V]
        in_range = col < cols
        colc = jnp.where(in_range, col, 0)
        img = colc // (ho * wo)
        rem = colc % (ho * wo)
        oy = rem // wo
        ox = rem % wo
        # Source pixel per (row, lane).
        hi = oy[None, :] * stride + row_ky[:, None] - pad    # [K, V]
        wi = ox[None, :] * stride + row_kx[:, None] - pad
        valid = (
            (hi >= 0) & (hi < h) & (wi >= 0) & (wi < w) & in_range[None, :]
        )
        hic = jnp.clip(hi, 0, h - 1)
        wic = jnp.clip(wi, 0, w - 1)
        flat = ((row_c[:, None] * n + img[None, :]) * h + hic) * w + wic
        vals = xf[flat.reshape(-1)].reshape(k, v)
        o_ref[0] = jnp.where(valid, vals, 0.0)

    return pl.pallas_call(
        kernel,
        grid=(strips,),
        in_specs=[pl.BlockSpec((c_in, n, h, w), lambda s: (0, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, k, v), lambda s: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((strips, k, v), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(x, jnp.float32))
