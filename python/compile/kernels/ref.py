"""Pure-jnp / numpy oracles for every Pallas kernel (the CORE
correctness signal), plus the pruning/compression helpers that mirror
``rust/src/pruning`` exactly.

All matrices follow the Rust conventions:
  * filter matrix ``W[rows, K]`` with K = Kh*Kw*C_in, rows ordered
    kernel-position-major / input-channel-minor (OHWI flattening);
  * data matrix ``A[K, cols]`` with cols = N*H_out*W_out, (n, ho, wo)
    ordered, w innermost;
  * packed matrix ``[strips, K, V]`` with zero-padded tail strip.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


# ---------------------------------------------------------------------
# Pruning / compression (mirrors rust/src/pruning)

def retained_for_sparsity(m: int, sparsity: float) -> int:
    """N = round((1 - sparsity) * M), clamped to [0, M]."""
    return min(int(round((1.0 - sparsity) * m)), m)


def prune_colwise(w: np.ndarray, tile: int, n: int, m: int):
    """Column-wise N:M pruning (paper §3.1).

    Returns (mask, tiles) where tiles is a list of dicts with keys
    ``row_start``, ``row_count``, ``indices`` (sorted), ``values``
    [row_count, nret] — the compressed format Algorithm 1 consumes.
    """
    rows, cols = w.shape
    assert 1 <= n <= m
    mask = np.zeros_like(w, dtype=bool)
    tiles = []
    groups = -(-cols // m)  # ceil
    for row_start in range(0, rows, tile):
        row_count = min(tile, rows - row_start)
        block = w[row_start:row_start + row_count]
        keep: list[int] = []
        for g in range(groups):
            lo, hi = g * m, min((g + 1) * m, cols)
            scores = np.abs(block[:, lo:hi]).sum(axis=0)
            k = min(n, hi - lo)
            # ties broken by lower index, like the Rust top_n_indices
            order = np.lexsort((np.arange(hi - lo), -scores))[:k]
            keep.extend(sorted(lo + int(i) for i in order))
        keep_arr = np.array(keep, dtype=np.int32)
        mask[row_start:row_start + row_count, keep_arr] = True
        tiles.append({
            "row_start": row_start,
            "row_count": row_count,
            "indices": keep_arr,
            "values": block[:, keep_arr].astype(np.float32),
        })
    return mask, tiles


def prune_colwise_adaptive(w: np.ndarray, tile: int, sparsity: float):
    """Adaptive-M column-wise pruning: M = K, N from the sparsity ratio."""
    cols = w.shape[1]
    n = max(retained_for_sparsity(cols, sparsity), 1)
    return prune_colwise(w, tile, n, cols)


def prune_rownm(w: np.ndarray, n: int, m: int) -> np.ndarray:
    """Row-based N:M magnitude pruning mask (conventional baseline)."""
    rows, cols = w.shape
    mask = np.zeros_like(w, dtype=bool)
    for r in range(rows):
        for lo in range(0, cols, m):
            hi = min(lo + m, cols)
            k = min(n, hi - lo)
            scores = np.abs(w[r, lo:hi])
            order = np.lexsort((np.arange(hi - lo), -scores))[:k]
            mask[r, lo + order] = True
    return mask


def compress_rownm(w: np.ndarray, n: int, m: int):
    """Row-based N:M compressed format: (values, indices) each
    [rows, groups*n] (aligned cols only)."""
    rows, cols = w.shape
    assert cols % m == 0, "aligned columns required for compression"
    mask = prune_rownm(w, n, m)
    per_row = (cols // m) * n
    values = np.zeros((rows, per_row), np.float32)
    indices = np.zeros((rows, per_row), np.int32)
    for r in range(rows):
        idx = np.nonzero(mask[r])[0]
        assert len(idx) == per_row
        values[r] = w[r, idx]
        indices[r] = idx
    return values, indices


# ---------------------------------------------------------------------
# Data-matrix oracles

def im2col_cnhw(x: np.ndarray, kh: int, kw: int, stride: int, pad: int) -> np.ndarray:
    """im2col over CNHW input -> A[K, N*Ho*Wo], zero padding."""
    c_in, n, h, w = x.shape
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    cols = n * ho * wo
    a = np.zeros((kh * kw * c_in, cols), np.float32)
    xp = np.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    for ky in range(kh):
        for kx in range(kw):
            for c in range(c_in):
                row = (ky * kw + kx) * c_in + c
                patch = xp[c, :, ky:ky + ho * stride:stride, kx:kx + wo * stride:stride]
                a[row] = patch.reshape(cols)
    return a


def pack_data_matrix(a: np.ndarray, v: int) -> np.ndarray:
    """Pack A[K, cols] into [strips, K, V] with zero-padded tail."""
    k, cols = a.shape
    strips = max(-(-cols // v), 1)
    out = np.zeros((strips, k, v), np.float32)
    for s in range(strips):
        valid = min(v, cols - s * v)
        if valid > 0:
            out[s, :, :valid] = a[:, s * v:s * v + valid]
    return out


def fused_im2col_pack_ref(x, kh, kw, stride, pad, v):
    """Reference for the fused kernel = pack(im2col(x))."""
    return pack_data_matrix(im2col_cnhw(np.asarray(x), kh, kw, stride, pad), v)


# ---------------------------------------------------------------------
# GEMM oracles

def matmul_ref(w, a):
    """Dense C = W @ A (jnp, f32)."""
    return jnp.asarray(w, jnp.float32) @ jnp.asarray(a, jnp.float32)


def spmm_colwise_ref(w: np.ndarray, tile: int, n: int, m: int, a: np.ndarray):
    """Column-wise sparse GEMM oracle: masked dense matmul."""
    mask, _ = prune_colwise(w, tile, n, m)
    return matmul_ref(np.where(mask, w, 0.0), a)


def spmm_rownm_ref(w: np.ndarray, n: int, m: int, a: np.ndarray):
    """Row-based N:M sparse GEMM oracle."""
    mask = prune_rownm(w, n, m)
    return matmul_ref(np.where(mask, w, 0.0), a)


def conv2d_ref_cnhw(x, w_oihw, stride: int, pad: int):
    """Direct convolution oracle over CNHW input / OIHW weights,
    returning CNHW output — via the im2col + filter-matrix route (itself
    verified against jax.lax.conv in tests)."""
    x = np.asarray(x, np.float32)
    w = np.asarray(w_oihw, np.float32)
    c_out, c_in, kh, kw = w.shape
    _, n, h, win = x.shape
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (win + 2 * pad - kw) // stride + 1
    a = im2col_cnhw(x, kh, kw, stride, pad)
    f = w.transpose(0, 2, 3, 1).reshape(c_out, kh * kw * c_in)  # OHWI flat
    out = f @ a
    return out.reshape(c_out, n, ho, wo)
