"""Layer-1 Pallas kernel: Algorithm 1 — column-wise N:M sparse GEMM.

TPU adaptation of the paper's RVV micro-kernel (DESIGN.md
§Hardware-Adaptation): because every row of a T-row tile shares one
retained-column index set, the kernel gathers the N retained rows of the
packed data strip **once** into VMEM and contracts them against the
compressed ``(T, N)`` value block as a dense MXU matmul — the
compressed-operand formulation. Row-based N:M cannot do this (each row
would need its own gather; see ``nm_row_spmm.py``).

Grid: (strips, tiles). Per step the VMEM working set is
``K·V + T·N + T·V`` f32 words — the BlockSpec analogue of the paper's
register budget ``(T+1)·LMUL ≤ 32``.

Pallas runs with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO, which is what
the Rust runtime loads. Real-TPU performance is estimated from the VMEM
footprint + MXU utilisation in DESIGN.md §Perf.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def pack_colwise_weights(w: np.ndarray, tile: int, n: int, m: int):
    """Compress ``w[rows, cols]`` into the kernel operands:

    Returns (w_vals [ntiles, T, NRET] f32, idx [ntiles, NRET] i32, rows).
    The tail tile is padded with zero rows; NRET is uniform because the
    aligned N:M grouping retains the same count per tile.
    """
    rows, _ = w.shape
    _, tiles = ref.prune_colwise(w, tile, n, m)
    nret = len(tiles[0]["indices"])
    ntiles = len(tiles)
    w_vals = np.zeros((ntiles, tile, nret), np.float32)
    idx = np.zeros((ntiles, nret), np.int32)
    for ti, t in enumerate(tiles):
        assert len(t["indices"]) == nret, "aligned N:M gives uniform NRET"
        w_vals[ti, : t["row_count"]] = t["values"]
        idx[ti] = t["indices"]
    return w_vals, idx, rows


def colwise_spmm(a_packed, w_vals, idx, *, interpret: bool = True):
    """Sparse GEMM: ``C = W_compressed · A``.

    a_packed: [strips, K, V]   packed data matrix
    w_vals:   [ntiles, T, N]   compressed tile values
    idx:      [ntiles, N] i32  shared retained-column indices per tile
    returns:  [ntiles*T, strips*V] (caller crops rows/cols)
    """
    strips, k, v = a_packed.shape
    ntiles, t, nret = w_vals.shape
    # idx may arrive as f32 (the AOT path marshals f32 only — HLO text
    # elides large constants, so weights/indices are runtime parameters).
    idx = jnp.asarray(idx).astype(jnp.int32)

    def kernel(a_ref, w_ref, idx_ref, o_ref):
        a = a_ref[0]            # [K, V] strip resident in VMEM
        wv = w_ref[0]           # [T, N] compressed values
        ix = idx_ref[0]         # [N]
        gathered = jnp.take(a, ix, axis=0)  # one gather per *tile*
        # Dense (T,N)x(N,V) contraction over the compressed operands:
        # (1 - sparsity) of the dense FLOPs, MXU-friendly.
        o_ref[0, :, 0, :] = wv @ gathered

    out = pl.pallas_call(
        kernel,
        grid=(strips, ntiles),
        in_specs=[
            pl.BlockSpec((1, k, v), lambda s, ti: (s, 0, 0)),
            pl.BlockSpec((1, t, nret), lambda s, ti: (ti, 0, 0)),
            pl.BlockSpec((1, nret), lambda s, ti: (ti, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, 1, v), lambda s, ti: (ti, 0, s, 0)),
        out_shape=jax.ShapeDtypeStruct((ntiles, t, strips, v), jnp.float32),
        interpret=interpret,
    )(a_packed, w_vals, idx)
    return out.transpose(0, 1, 2, 3).reshape(ntiles * t, strips * v)


def colwise_spmm_dense_result(w: np.ndarray, a: np.ndarray, tile: int, n: int, m: int, v: int):
    """End-to-end helper: prune + compress + pack + kernel, returning the
    ``[rows, cols]`` result (test convenience)."""
    rows, _ = w.shape
    cols = a.shape[1]
    w_vals, idx, _ = pack_colwise_weights(w, tile, n, m)
    packed = jnp.asarray(ref.pack_data_matrix(a, v))
    out = colwise_spmm(packed, jnp.asarray(w_vals), jnp.asarray(idx))
    return out[:rows, :cols]
