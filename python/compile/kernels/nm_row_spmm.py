"""Layer-1 Pallas kernel: conventional row-based N:M SpMM baseline.

Contrast with ``colwise_spmm``: every output row carries its *own*
retained-column index array, so the kernel must gather per row —
``(T, PR, V)`` intermediate instead of one shared ``(N, V)`` gather —
and the contraction degrades from one MXU matmul to a broadcast-multiply
reduction. This is the TPU manifestation of the redundant-access
pathology the paper identifies on RVV (§3.1).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def rownm_spmm(a_packed, values, indices, tile: int, *, interpret: bool = True):
    """``C = W_rowNM · A``.

    a_packed: [strips, K, V]
    values:   [rows, PR] retained values (rows padded to tile multiple)
    indices:  [rows, PR] i32 column of each value
    returns:  [rows, strips*V]
    """
    strips, k, v = a_packed.shape
    rows, pr = values.shape
    rows_pad = -(-rows // tile) * tile
    if rows_pad != rows:
        values = jnp.concatenate(
            [jnp.asarray(values), jnp.zeros((rows_pad - rows, pr), jnp.float32)]
        )
        indices = jnp.concatenate(
            [jnp.asarray(indices), jnp.zeros((rows_pad - rows, pr), jnp.int32)]
        )
    row_tiles = rows_pad // tile

    def kernel(a_ref, vals_ref, idx_ref, o_ref):
        a = a_ref[0]                       # [K, V]
        vals = vals_ref[...]               # [T, PR]
        ix = idx_ref[...]                  # [T, PR]
        gathered = jnp.take(a, ix.reshape(-1), axis=0).reshape(
            vals.shape[0], vals.shape[1], a.shape[1]
        )                                  # per-row gather: [T, PR, V]
        o_ref[:, 0, :] = (vals[:, :, None] * gathered).sum(axis=1)

    out = pl.pallas_call(
        kernel,
        grid=(strips, row_tiles),
        in_specs=[
            pl.BlockSpec((1, k, v), lambda s, rt: (s, 0, 0)),
            pl.BlockSpec((tile, pr), lambda s, rt: (rt, 0)),
            pl.BlockSpec((tile, pr), lambda s, rt: (rt, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1, v), lambda s, rt: (rt, s, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, strips, v), jnp.float32),
        interpret=interpret,
    )(a_packed, jnp.asarray(values, jnp.float32), jnp.asarray(indices, jnp.int32))
    return out.reshape(rows_pad, strips * v)[:rows]


def rownm_spmm_result(w: np.ndarray, a: np.ndarray, n: int, m: int, tile: int, v: int):
    """compress + pack + kernel, cropped to [rows, cols]."""
    from . import ref

    cols = a.shape[1]
    values, indices = ref.compress_rownm(w, n, m)
    packed = jnp.asarray(ref.pack_data_matrix(a, v))
    return rownm_spmm(packed, values, indices, tile)[:, :cols]
