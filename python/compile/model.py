"""Layer-2 JAX model: CNN forward pass composed from the Pallas kernels.

Two twin forward paths:

* ``small_cnn_fwd_kernels`` — the *deployment* path: NHWC→CNHW layout
  transform, fused im2col+pack (Algorithm 2) and column-wise sparse /
  dense GEMM Pallas kernels per conv layer. This is what ``aot.py``
  lowers to HLO text for the Rust runtime.
* ``small_cnn_fwd_jnp`` — the *training* path: plain ``jax.lax`` convs
  with optional pruning masks, fast enough for the accuracy experiments
  in ``train_prune.py``. Tests assert the two paths agree.

The model ("smallcnn") is the synthetic-task stand-in for the paper's
ImageNet CNNs (see DESIGN.md §2: accuracy claims are ordinal and
architecture-independent; the Rust model zoo carries the real
ResNet/MobileNet/DenseNet geometry for the performance experiments).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import (
    colwise_spmm,
    dense_gemm,
    fused_im2col_pack,
    pack_colwise_weights,
    ref,
)

# ---------------------------------------------------------------------
# Parameters

LAYERS = (
    # name,   c_in, c_out, k, stride, pad
    ("conv1", 3, 16, 3, 1, 1),
    ("conv2", 16, 32, 3, 2, 1),
    ("conv3", 32, 32, 3, 1, 1),
)
NUM_CLASSES = 10


def init_params(seed: int = 0) -> dict:
    """He-initialised weights as numpy arrays (OIHW convs + FC)."""
    rng = np.random.default_rng(seed)
    params: dict = {}
    for name, c_in, c_out, k, _, _ in LAYERS:
        scale = np.sqrt(2.0 / (c_in * k * k))
        params[name] = rng.normal(0, scale, (c_out, c_in, k, k)).astype(np.float32)
    params["fc_w"] = rng.normal(0, np.sqrt(1.0 / 32), (NUM_CLASSES, 32)).astype(np.float32)
    params["fc_b"] = np.zeros(NUM_CLASSES, np.float32)
    return params


def filter_matrix(w_oihw) -> np.ndarray:
    """OIHW → the GEMM filter matrix [C_out, Kh*Kw*C_in] (k-major,
    channel-inner) — matches rust `oihw_to_filter_matrix`."""
    w = np.asarray(w_oihw)
    o, i, kh, kw = w.shape
    return w.transpose(0, 2, 3, 1).reshape(o, kh * kw * i)


# ---------------------------------------------------------------------
# Deployment path (Pallas kernels)

def conv2d_kernels_dense(x_cnhw, f_matrix, *, kh: int, kw: int, stride: int,
                         pad: int, v: int, tile: int = 8):
    """Dense conv on the kernel path with the filter matrix as a runtime
    operand (AOT artifacts must not bake weights as constants: the HLO
    text printer elides large literals and the old parser zero-fills
    them — see aot.py)."""
    c_in, n, h, w_in = x_cnhw.shape
    c_out = f_matrix.shape[0]
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w_in + 2 * pad - kw) // stride + 1
    cols = n * ho * wo
    packed = fused_im2col_pack(x_cnhw, kh, kw, stride, pad, v)
    out = dense_gemm(packed, f_matrix, tile)
    return out[:c_out, :cols].reshape(c_out, n, ho, wo)


def conv2d_kernels_sparse(x_cnhw, w_vals, idx, *, c_out: int, kh: int,
                          kw: int, stride: int, pad: int, v: int):
    """Column-wise sparse conv on the kernel path with the compressed
    operands (values [ntiles,T,N], indices [ntiles,N], possibly f32) as
    runtime parameters."""
    c_in, n, h, w_in = x_cnhw.shape
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w_in + 2 * pad - kw) // stride + 1
    cols = n * ho * wo
    packed = fused_im2col_pack(x_cnhw, kh, kw, stride, pad, v)
    out = colwise_spmm(packed, w_vals, idx)
    return out[:c_out, :cols].reshape(c_out, n, ho, wo)


def conv2d_kernels(x_cnhw, w_oihw, *, stride: int, pad: int, v: int,
                   tile: int = 8, sparsity: float | None = None):
    """One conv layer on the kernel path: fused im2col/pack → GEMM.

    ``sparsity=None`` → dense GEMM kernel; otherwise adaptive-M
    column-wise pruning at that ratio (compression happens at trace time
    — weights are static).
    Returns CNHW output.
    """
    c_in, n, h, w_in = x_cnhw.shape
    c_out, _, kh, kw = np.asarray(w_oihw).shape
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w_in + 2 * pad - kw) // stride + 1
    cols = n * ho * wo
    f = filter_matrix(w_oihw)
    packed = fused_im2col_pack(x_cnhw, kh, kw, stride, pad, v)
    if sparsity is None:
        out = dense_gemm(packed, f, tile)
    else:
        nret = max(ref.retained_for_sparsity(f.shape[1], sparsity), 1)
        w_vals, idx, _ = pack_colwise_weights(f, tile, nret, f.shape[1])
        out = colwise_spmm(packed, jnp.asarray(w_vals), jnp.asarray(idx))
    return out[:c_out, :cols].reshape(c_out, n, ho, wo)


def small_cnn_fwd_kernels(params: dict, x_nhwc, *, v: int = 32,
                          tile: int = 8, sparsity: float | None = None):
    """Full smallcnn forward on the kernel path. NHWC input → logits.

    Layout policy mirrors the paper (§4.1.2): NHWC→CNHW before the first
    conv, CNHW throughout, and the first conv is never pruned.
    """
    x = jnp.transpose(jnp.asarray(x_nhwc, jnp.float32), (3, 0, 1, 2))  # → CNHW
    for li, (name, _, _, _, stride, pad) in enumerate(LAYERS):
        sp = None if li == 0 else sparsity  # never prune the first conv
        x = conv2d_kernels(x, params[name], stride=stride, pad=pad, v=v,
                           tile=tile, sparsity=sp)
        x = jnp.maximum(x, 0.0)
    # Global average pool: CNHW → [N, C].
    feat = x.mean(axis=(2, 3)).T
    return feat @ jnp.asarray(params["fc_w"]).T + jnp.asarray(params["fc_b"])


def small_cnn_operands(params: dict, *, tile: int = 8,
                       sparsity: float = 0.5) -> list[np.ndarray]:
    """Flatten smallcnn weights into the runtime-operand list the AOT
    artifact takes: [conv1 filter, conv2 vals, conv2 idx, conv3 vals,
    conv3 idx, fc_w, fc_b]. Indices are f32 (the runtime marshals f32;
    the kernel casts back). Compression happens here — host side, once."""
    out: list[np.ndarray] = [filter_matrix(params["conv1"])]
    for name in ("conv2", "conv3"):
        f = filter_matrix(params[name])
        nret = max(ref.retained_for_sparsity(f.shape[1], sparsity), 1)
        w_vals, idx, _ = pack_colwise_weights(f, tile, nret, f.shape[1])
        out.append(w_vals)
        out.append(idx.astype(np.float32))
    out.append(params["fc_w"])
    out.append(params["fc_b"])
    return out


def small_cnn_fwd_operands(x_nhwc, conv1_f, c2_vals, c2_idx, c3_vals, c3_idx,
                           fc_w, fc_b, *, v: int = 32, tile: int = 8):
    """smallcnn forward with every weight as a runtime operand — the AOT
    entrypoint (arity 8). Numerically identical to
    ``small_cnn_fwd_kernels`` at the same sparsity."""
    x = jnp.transpose(jnp.asarray(x_nhwc, jnp.float32), (3, 0, 1, 2))
    (_, _, c1out, k1, s1, p1) = LAYERS[0]
    x = conv2d_kernels_dense(x, conv1_f, kh=k1, kw=k1, stride=s1, pad=p1,
                             v=v, tile=tile)
    x = jnp.maximum(x, 0.0)
    for (vals, idx), (_, _, c_out, k, stride, pad) in zip(
        [(c2_vals, c2_idx), (c3_vals, c3_idx)], LAYERS[1:]
    ):
        x = conv2d_kernels_sparse(x, vals, idx, c_out=c_out, kh=k, kw=k,
                                  stride=stride, pad=pad, v=v)
        x = jnp.maximum(x, 0.0)
    feat = x.mean(axis=(2, 3)).T
    return feat @ jnp.asarray(fc_w).T + jnp.asarray(fc_b)


# ---------------------------------------------------------------------
# Residual block (ResNet BasicBlock) on the kernel path — exercises the
# skip-connection composition the Rust model zoo uses, end to end
# through the Pallas kernels, and is AOT-lowered as its own artifact.

def init_resblock_params(c: int, seed: int = 1) -> dict:
    """Two 3×3 convs at width ``c`` (identity skip)."""
    rng = np.random.default_rng(seed)
    scale = np.sqrt(2.0 / (c * 9))
    return {
        "rb_conv1": rng.normal(0, scale, (c, c, 3, 3)).astype(np.float32),
        "rb_conv2": rng.normal(0, scale, (c, c, 3, 3)).astype(np.float32),
    }


def resblock_fwd_kernels(params: dict, x_cnhw, *, v: int = 32,
                         tile: int = 8, sparsity: float | None = 0.5):
    """BasicBlock on the kernel path: conv-relu-conv + identity, relu.

    Input and output are CNHW ``[C, N, H, W]`` (stride 1, pad 1 keeps
    the geometry, so the skip is a plain add).
    """
    h = conv2d_kernels(x_cnhw, params["rb_conv1"], stride=1, pad=1, v=v,
                       tile=tile, sparsity=sparsity)
    h = jnp.maximum(h, 0.0)
    h = conv2d_kernels(h, params["rb_conv2"], stride=1, pad=1, v=v,
                       tile=tile, sparsity=sparsity)
    return jnp.maximum(h + x_cnhw, 0.0)


def resblock_fwd_jnp(params: dict, x_cnhw, masks: dict | None = None):
    """lax-conv twin of :func:`resblock_fwd_kernels` (mask-aware)."""
    def w(name):
        wt = jnp.asarray(params[name], jnp.float32)
        if masks and name in masks:
            wt = wt * jnp.asarray(masks[name], jnp.float32)
        return wt

    h = jnp.maximum(conv2d_jnp(x_cnhw, w("rb_conv1"), 1, 1), 0.0)
    h = conv2d_jnp(h, w("rb_conv2"), 1, 1)
    return jnp.maximum(h + x_cnhw, 0.0)


def resblock_operands(params: dict, *, tile: int = 8,
                      sparsity: float = 0.5) -> list[np.ndarray]:
    """Compressed runtime operands [c1_vals, c1_idx, c2_vals, c2_idx]."""
    out: list[np.ndarray] = []
    for name in ("rb_conv1", "rb_conv2"):
        f = filter_matrix(params[name])
        nret = max(ref.retained_for_sparsity(f.shape[1], sparsity), 1)
        w_vals, idx, _ = pack_colwise_weights(f, tile, nret, f.shape[1])
        out.append(w_vals)
        out.append(idx.astype(np.float32))
    return out


def resblock_fwd_operands(x_cnhw, c1_vals, c1_idx, c2_vals, c2_idx, *,
                          c: int, v: int = 32):
    """Residual block with compressed weights as runtime operands — the
    AOT entrypoint (arity 5)."""
    h = conv2d_kernels_sparse(x_cnhw, c1_vals, c1_idx, c_out=c, kh=3, kw=3,
                              stride=1, pad=1, v=v)
    h = jnp.maximum(h, 0.0)
    h = conv2d_kernels_sparse(h, c2_vals, c2_idx, c_out=c, kh=3, kw=3,
                              stride=1, pad=1, v=v)
    return jnp.maximum(h + x_cnhw, 0.0)


# ---------------------------------------------------------------------
# Training path (lax convs, maskable)

def conv2d_jnp(x_cnhw, w_oihw, stride: int, pad: int):
    """lax conv over CNHW activations (via NCHW internally)."""
    x_nchw = jnp.transpose(x_cnhw, (1, 0, 2, 3))
    y = jax.lax.conv_general_dilated(
        x_nchw,
        jnp.asarray(w_oihw, jnp.float32),
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return jnp.transpose(y, (1, 0, 2, 3))


def small_cnn_fwd_jnp(params: dict, x_nhwc, masks: dict | None = None):
    """Training-path forward. ``masks`` maps layer name → boolean mask on
    the *filter matrix* [C_out, K]; applied multiplicatively so gradients
    flow only to retained weights (mask-projected fine-tuning)."""
    x = jnp.transpose(jnp.asarray(x_nhwc, jnp.float32), (3, 0, 1, 2))
    for name, c_in, _, k, stride, pad in LAYERS:
        w = jnp.asarray(params[name], jnp.float32)
        if masks and name in masks:
            o = w.shape[0]
            m = jnp.asarray(masks[name], jnp.float32).reshape(o, k, k, c_in)
            # filter-matrix mask (OHWI order) back onto OIHW weights
            w = w * jnp.transpose(m, (0, 3, 1, 2))
        x = conv2d_jnp(x, w, stride, pad)
        x = jnp.maximum(x, 0.0)
    feat = x.mean(axis=(2, 3)).T
    return feat @ jnp.asarray(params["fc_w"]).T + jnp.asarray(params["fc_b"])


# ---------------------------------------------------------------------
# Synthetic dataset ("synthnet"): deterministic 10-class image task

def synth_batch(rng: np.random.Generator, n: int, res: int = 16):
    """Class-conditional images: fixed per-class pattern + noise.

    The patterns are drawn once from a *fixed* seed so train/test share
    the class structure while samples differ.
    """
    pat_rng = np.random.default_rng(1234)
    patterns = pat_rng.normal(0, 1, (NUM_CLASSES, res, res, 3)).astype(np.float32)
    labels = rng.integers(0, NUM_CLASSES, n)
    noise = rng.normal(0, 1.0, (n, res, res, 3)).astype(np.float32)
    x = patterns[labels] + noise
    return x.astype(np.float32), labels.astype(np.int32)


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -logp[jnp.arange(labels.shape[0]), labels].mean()


def accuracy(logits, labels) -> float:
    return float((jnp.argmax(logits, axis=1) == labels).mean())
