"""Build-time compile path: Pallas kernels (L1), the JAX model (L2) and
the AOT lowering to HLO text consumed by the Rust runtime (L3).
Python never runs on the request path.
"""
