"""L2 model tests: the Pallas-kernel deployment path must agree with the
lax training path, and the AOT operand path with both."""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def params():
    return model.init_params(seed=0)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(5)
    return model.synth_batch(rng, 4)


def test_dense_paths_agree(params, batch):
    x, _ = batch
    jnp_logits = np.asarray(model.small_cnn_fwd_jnp(params, x))
    kern_logits = np.asarray(model.small_cnn_fwd_kernels(params, x, v=16))
    assert jnp_logits.shape == (4, model.NUM_CLASSES)
    np.testing.assert_allclose(kern_logits, jnp_logits, rtol=1e-3, atol=1e-4)


def test_sparse_kernel_path_matches_masked_jnp(params, batch):
    x, _ = batch
    sparsity = 0.5
    masks = {
        name: ref.prune_colwise_adaptive(
            model.filter_matrix(params[name]), 8, sparsity
        )[0]
        for name in ("conv2", "conv3")
    }
    want = np.asarray(model.small_cnn_fwd_jnp(params, x, masks))
    got = np.asarray(
        model.small_cnn_fwd_kernels(params, x, v=16, tile=8, sparsity=sparsity)
    )
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_operand_path_matches_kernel_path(params, batch):
    x, _ = batch
    ops = model.small_cnn_operands(params, tile=8, sparsity=0.5)
    got = np.asarray(model.small_cnn_fwd_operands(x, *ops, v=16, tile=8))
    want = np.asarray(
        model.small_cnn_fwd_kernels(params, x, v=16, tile=8, sparsity=0.5)
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_first_conv_never_pruned(params):
    ops = model.small_cnn_operands(params, sparsity=0.75)
    conv1 = ops[0]
    # conv1 operand is the *dense* filter matrix, untouched.
    np.testing.assert_array_equal(conv1, model.filter_matrix(params["conv1"]))


def test_operand_shapes(params):
    ops = model.small_cnn_operands(params, tile=8, sparsity=0.5)
    assert len(ops) == 7
    conv1, c2v, c2i, c3v, c3i, fc_w, fc_b = ops
    assert conv1.shape == (16, 27)
    assert c2v.shape[0] == 4 and c2v.shape[1] == 8  # 32 rows / tile 8
    assert c2i.shape == (4, c2v.shape[2])
    assert fc_w.shape == (10, 32) and fc_b.shape == (10,)
    # 50% sparsity → half the K columns retained.
    assert c2v.shape[2] == 16 * 9 // 2


def test_synth_batch_deterministic_patterns():
    a, la = model.synth_batch(np.random.default_rng(1), 64)
    b, lb = model.synth_batch(np.random.default_rng(1), 64)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)
    # Different sample seed → different noise, same class structure.
    c, _ = model.synth_batch(np.random.default_rng(2), 64)
    assert not np.array_equal(a, c)


def test_training_reduces_loss_quickly():
    from compile.train_prune import train, evaluate

    params = train(model.init_params(seed=0), steps=80, seed=3)
    acc = evaluate(params, n=400)
    assert acc > 0.5, f"synthetic task should be learnable fast, got {acc}"


# ---------------------------------------------------------------------
# Residual block (kernel path vs lax twin, operands entrypoint)

def test_resblock_kernel_path_matches_jnp_when_dense():
    rb = model.init_resblock_params(8, seed=5)
    rng = np.random.default_rng(6)
    x = rng.normal(0, 1, (8, 2, 10, 10)).astype(np.float32)
    got = np.asarray(model.resblock_fwd_kernels(rb, x, v=16, sparsity=None))
    want = np.asarray(model.resblock_fwd_jnp(rb, x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_resblock_operand_entrypoint_matches_traced_sparse():
    rb = model.init_resblock_params(8, seed=7)
    rng = np.random.default_rng(8)
    x = rng.normal(0, 1, (8, 1, 12, 12)).astype(np.float32)
    ops = model.resblock_operands(rb, tile=8, sparsity=0.5)
    got = np.asarray(model.resblock_fwd_operands(
        x, *[np.asarray(o) for o in ops], c=8, v=16))
    want = np.asarray(model.resblock_fwd_kernels(rb, x, v=16, tile=8,
                                                 sparsity=0.5))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_resblock_preserves_geometry_and_skip():
    rb = model.init_resblock_params(4, seed=9)
    x = np.zeros((4, 1, 6, 6), np.float32)
    y = np.asarray(model.resblock_fwd_kernels(rb, x, v=8, sparsity=0.5))
    assert y.shape == x.shape
    # Zero input + relu chain -> zero output through the identity skip.
    np.testing.assert_array_equal(y, np.zeros_like(y))
