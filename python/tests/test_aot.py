"""AOT lowering tests: HLO text generation, the flat-f32 interchange
format, and the no-elided-constants invariant that bit the runtime."""

import os
import re

import numpy as np
import jax
import jax.numpy as jnp

from compile import model
from compile.aot import save_flat, to_hlo_text


def test_to_hlo_text_produces_parseable_module():
    def fn(x, y):
        return (x @ y + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "ENTRY" in text
    assert "parameter(0)" in text and "parameter(1)" in text


def test_pallas_kernel_lowering_has_no_large_elided_constants():
    """Weights must be parameters: `constant({...})` in the HLO text is
    zero-filled by the old parser (the conv_dense bug)."""
    params = model.init_params(seed=0)
    ops = model.small_cnn_operands(params, tile=8, sparsity=0.5)
    x = np.zeros((1, 16, 16, 3), np.float32)

    def fwd(xb, *o):
        return (model.small_cnn_fwd_operands(xb, *o, v=32, tile=8),)

    specs = [jax.ShapeDtypeStruct(np.asarray(a).shape, jnp.float32)
             for a in [x] + ops]
    text = to_hlo_text(jax.jit(fwd).lower(*specs))
    assert "ENTRY" in text
    # The printer elides any large literal as `constant({...})`.
    assert re.search(r"constant\(\{\.\.\.", text) is None, \
        "elided constant found — a weight was baked instead of passed"


def test_save_flat_roundtrip(tmp_path):
    arr = np.random.default_rng(0).normal(size=(3, 4, 5)).astype(np.float32)
    p = tmp_path / "x.txt"
    save_flat(str(p), arr)
    lines = p.read_text().strip().splitlines()
    dims = tuple(int(t) for t in lines[0].split())
    vals = np.array([float(v) for v in lines[1:]], np.float32).reshape(dims)
    np.testing.assert_allclose(vals, arr, rtol=1e-6, atol=0)


def test_artifacts_dir_contents_if_generated():
    """When `make artifacts` has run, the manifest must reference files
    that exist with consistent arities."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.tsv")
    if not os.path.exists(manifest):
        return  # not generated yet
    with open(manifest) as f:
        for line in f:
            if line.startswith("#") or not line.strip():
                continue
            name, fname, arity, _ = line.rstrip("\n").split("\t")
            assert os.path.exists(os.path.join(art, fname)), fname
            for i in range(int(arity)):
                assert os.path.exists(
                    os.path.join(art, f"{name}.input{i}.txt")
                ), f"{name}.input{i}"
            assert os.path.exists(os.path.join(art, f"{name}.expected0.txt"))
