"""Properties of the reference pruning/compression helpers (they must
mirror rust/src/pruning exactly — the Rust side has the same tests)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 20),
    kgroups=st.integers(1, 8),
    tile=st.integers(1, 8),
    n=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_colwise_mask_structure(rows, kgroups, tile, n, seed):
    cols = 4 * kgroups
    w = rand((rows, cols), seed)
    mask, tiles = ref.prune_colwise(w, tile, n, 4)
    # Exactly n columns kept per group per tile; identical across the
    # tile's rows (the column-wise constraint).
    for t in tiles:
        rs, rc = t["row_start"], t["row_count"]
        block = mask[rs:rs + rc]
        assert (block == block[0]).all(), "rows of a tile share the mask"
        for g in range(kgroups):
            assert block[0, 4 * g:4 * g + 4].sum() == n
    # Sparsity is exact for aligned groups.
    assert abs((1 - mask.mean()) - (1 - n / 4)) < 1e-9


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 16),
    kgroups=st.integers(1, 8),
    n=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_rownm_keeps_largest_per_group(rows, kgroups, n, seed):
    cols = 4 * kgroups
    w = rand((rows, cols), seed)
    mask = ref.prune_rownm(w, n, 4)
    for r in range(rows):
        for g in range(kgroups):
            grp = slice(4 * g, 4 * g + 4)
            kept = np.abs(w[r, grp])[mask[r, grp]]
            dropped = np.abs(w[r, grp])[~mask[r, grp]]
            assert mask[r, grp].sum() == n
            if len(kept) and len(dropped):
                assert kept.min() >= dropped.max() - 1e-7


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 16),
    cols=st.integers(8, 64),
    tile=st.integers(1, 8),
    sparsity=st.sampled_from([0.25, 0.5, 0.75]),
    seed=st.integers(0, 10_000),
)
def test_adaptive_sparsity_close_to_target(rows, cols, tile, sparsity, seed):
    w = rand((rows, cols), seed)
    mask, _ = ref.prune_colwise_adaptive(w, tile, sparsity)
    assert abs((1 - mask.mean()) - sparsity) < 0.1


def test_colwise_l1_scoring_sums_tile_rows():
    # Column 1's single large value outweighs column 0's two small ones.
    w = np.array([[1.0, 10.0], [1.0, 0.0]], np.float32)
    mask, tiles = ref.prune_colwise(w, 2, 1, 2)
    np.testing.assert_array_equal(mask, [[False, True], [False, True]])
    np.testing.assert_array_equal(tiles[0]["indices"], [1])


def test_compress_rownm_roundtrip():
    w = rand((6, 16), 7)
    values, indices = ref.compress_rownm(w, 2, 4)
    dense = np.zeros_like(w)
    for r in range(6):
        dense[r, indices[r]] = values[r]
    mask = ref.prune_rownm(w, 2, 4)
    np.testing.assert_array_equal(dense, np.where(mask, w, 0.0))


def test_tile_one_equals_rowwise_l1():
    # §4.5 config 1: column-wise with T=1 degenerates to per-row N:M.
    w = rand((5, 12), 9)
    mask_col, _ = ref.prune_colwise(w, 1, 2, 4)
    mask_row = ref.prune_rownm(w, 2, 4)
    np.testing.assert_array_equal(mask_col, mask_row)
