"""Fused im2col+pack kernel vs reference, and the reference itself vs
jax.lax convolution (closing the oracle loop)."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_im2col_pack, ref


def rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(
    c=st.integers(1, 5),
    n=st.integers(1, 3),
    h=st.integers(3, 12),
    w=st.integers(3, 12),
    k=st.sampled_from([1, 3]),
    stride=st.integers(1, 2),
    pad=st.integers(0, 1),
    v=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 10_000),
)
def test_fused_kernel_matches_ref(c, n, h, w, k, stride, pad, v, seed):
    if h + 2 * pad < k or w + 2 * pad < k:
        return
    x = rand((c, n, h, w), seed)
    got = np.asarray(fused_im2col_pack(x, k, k, stride, pad, v))
    want = ref.fused_im2col_pack_ref(x, k, k, stride, pad, v)
    np.testing.assert_array_equal(got, want)


def test_fused_kernel_stem_geometry():
    # ResNet stem: 7x7 stride 2 pad 3 (the §4.3 stride-2 case).
    x = rand((3, 1, 20, 20), 1)
    got = np.asarray(fused_im2col_pack(x, 7, 7, 2, 3, 32))
    want = ref.fused_im2col_pack_ref(x, 7, 7, 2, 3, 32)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    c_in=st.integers(1, 4),
    c_out=st.integers(1, 4),
    n=st.integers(1, 2),
    hw=st.integers(4, 10),
    stride=st.integers(1, 2),
    seed=st.integers(0, 10_000),
)
def test_ref_conv_matches_lax_conv(c_in, c_out, n, hw, stride, seed):
    """conv2d_ref_cnhw (im2col route) vs jax.lax.conv — validates the
    oracle the kernels are checked against."""
    x = rand((c_in, n, hw, hw), seed)
    w = rand((c_out, c_in, 3, 3), seed + 1)
    got = ref.conv2d_ref_cnhw(x, w, stride, 1)
    x_nchw = jnp.transpose(jnp.asarray(x), (1, 0, 2, 3))
    want = jax.lax.conv_general_dilated(
        x_nchw, jnp.asarray(w), (stride, stride), [(1, 1), (1, 1)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    want = np.asarray(jnp.transpose(want, (1, 0, 2, 3)))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_pack_tail_zero_padded():
    a = np.ones((2, 5), np.float32)
    p = ref.pack_data_matrix(a, 4)
    assert p.shape == (2, 2, 4)
    assert p[1, 0, 0] == 1.0 and (p[1, :, 1:] == 0).all()


def test_im2col_pointwise_is_reshape():
    x = rand((4, 2, 5, 5), 2)
    a = ref.im2col_cnhw(x, 1, 1, 1, 0)
    np.testing.assert_array_equal(a, x.reshape(4, -1))
