"""Pallas kernels vs pure-jnp oracles — the core correctness signal.

Hypothesis sweeps shapes, tile sizes and strip widths; every kernel must
match its reference within f32 tolerance.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    colwise_spmm_dense_result,
    dense_gemm_result,
    ref,
    rownm_spmm_result,
)

SETTINGS = dict(max_examples=25, deadline=None)


def rand(shape, seed):
    return np.random.default_rng(seed).normal(size=shape).astype(np.float32)


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 24),
    kgroups=st.integers(1, 6),
    cols=st.integers(1, 60),
    v=st.sampled_from([4, 8, 16, 32]),
    tile=st.integers(1, 8),
    n=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_colwise_spmm_matches_ref(rows, kgroups, cols, v, tile, n, seed):
    k = 4 * kgroups
    w = rand((rows, k), seed)
    a = rand((k, cols), seed + 1)
    got = np.asarray(colwise_spmm_dense_result(w, a, tile=tile, n=n, m=4, v=v))
    want = np.asarray(ref.spmm_colwise_ref(w, tile, n, 4, a))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 20),
    k=st.integers(1, 40),
    cols=st.integers(1, 50),
    v=st.sampled_from([4, 8, 16]),
    tile=st.integers(1, 8),
    seed=st.integers(0, 10_000),
)
def test_dense_gemm_matches_ref(rows, k, cols, v, tile, seed):
    w = rand((rows, k), seed)
    a = rand((k, cols), seed + 1)
    got = np.asarray(dense_gemm_result(w, a, tile=tile, v=v))
    np.testing.assert_allclose(got, w @ a, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 16),
    kgroups=st.integers(1, 5),
    cols=st.integers(1, 40),
    v=st.sampled_from([8, 16]),
    tile=st.integers(1, 4),
    n=st.integers(1, 4),
    seed=st.integers(0, 10_000),
)
def test_rownm_spmm_matches_ref(rows, kgroups, cols, v, tile, n, seed):
    k = 4 * kgroups
    w = rand((rows, k), seed)
    a = rand((k, cols), seed + 1)
    got = np.asarray(rownm_spmm_result(w, a, n=n, m=4, tile=tile, v=v))
    want = np.asarray(ref.spmm_rownm_ref(w, n, 4, a))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_colwise_adaptive_m_full_reduction():
    # Adaptive M = K at 75% sparsity keeps exactly K/4 columns per tile.
    w = rand((16, 64), 3)
    a = rand((64, 20), 4)
    mask, tiles = ref.prune_colwise_adaptive(w, 8, 0.75)
    assert all(len(t["indices"]) == 16 for t in tiles)
    got = np.asarray(
        colwise_spmm_dense_result(w, a, tile=8, n=16, m=64, v=8)
    )
    want = np.asarray(ref.matmul_ref(np.where(mask, w, 0.0), a))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_colwise_idx_accepts_f32():
    # The AOT path passes indices as f32; results must be identical.
    from compile.kernels import colwise_spmm, pack_colwise_weights
    import jax.numpy as jnp

    w = rand((8, 16), 5)
    a = rand((16, 24), 6)
    w_vals, idx, _ = pack_colwise_weights(w, 4, 2, 4)
    packed = jnp.asarray(ref.pack_data_matrix(a, 8))
    out_i = np.asarray(colwise_spmm(packed, jnp.asarray(w_vals), jnp.asarray(idx)))
    out_f = np.asarray(
        colwise_spmm(packed, jnp.asarray(w_vals), jnp.asarray(idx, jnp.float32))
    )
    np.testing.assert_array_equal(out_i, out_f)


@pytest.mark.parametrize("sparsity,expected", [(0.25, 3), (0.5, 2), (0.75, 1)])
def test_retained_for_sparsity_m4(sparsity, expected):
    assert ref.retained_for_sparsity(4, sparsity) == expected
